//! [`SynthRule`]: a verified (lhs → rhs) substitution pair packaged as a
//! first-class [`Rule`], so synthesised rules drop into the incremental
//! `MatchCache`/`DirtyRegion` matcher and the parallel search engine with
//! no special-casing.
//!
//! Matching is exact subgraph isomorphism on the lhs pattern: operator
//! attributes must match exactly, op-to-op edges must map, and pattern
//! sources bind (possibly non-injectively) to arbitrary producer ports in
//! the target graph. A site is reported only if the rhs *re-infers* to the
//! matched output descriptor at the bound shapes — so `apply` can never
//! fail a splice, which is the contract the environment's action masking
//! relies on.
//!
//! Rules verified only at the square enumeration shapes (`shape_generic ==
//! false`) additionally restrict matches to uniform square f32 bindings —
//! the shape class the random-testing validator actually covered.

use std::collections::HashMap;

use crate::graph::{canonical_hash, Graph, NodeId, OpKind, PortRef, TensorDesc};
use crate::xfer::apply::splice;
use crate::xfer::matcher::OpRelevance;
use crate::xfer::{Location, Rule};

use super::Tier;

/// A synthesised substitution rule (verified lhs → rhs pair).
pub struct SynthRule {
    name: &'static str,
    tier: Tier,
    shape_generic: bool,
    lhs: Graph,
    rhs: Graph,
    /// Live source ids of `lhs`, ascending. Position in this vector is the
    /// *source index* shared with `rhs_sources` (renaming correspondence).
    lhs_sources: Vec<NodeId>,
    /// Live op ids of `lhs`, ascending — a topological order, because
    /// patterns are compacted to forward-ordered form on construction.
    lhs_ops: Vec<NodeId>,
    lhs_out: NodeId,
    rhs_sources: Vec<NodeId>,
    rhs_ops: Vec<NodeId>,
    rhs_out: NodeId,
    relevance: OpRelevance,
}

fn sources_of(g: &Graph) -> Vec<NodeId> {
    let mut ids: Vec<NodeId> = g
        .live_ids()
        .filter(|&id| matches!(g.node(id).op, OpKind::Input | OpKind::Weight))
        .collect();
    ids.sort();
    ids
}

fn ops_of(g: &Graph) -> Vec<NodeId> {
    let mut ids: Vec<NodeId> = g
        .live_ids()
        .filter(|&id| !matches!(g.node(id).op, OpKind::Input | OpKind::Weight))
        .collect();
    ids.sort();
    ids
}

/// Source indices (positions in `sources`) that some op of `g` reads.
fn used_sources(g: &Graph, sources: &[NodeId]) -> Vec<bool> {
    let mut used = vec![false; sources.len()];
    for id in g.live_ids() {
        for inp in &g.node(id).inputs {
            if let Some(si) = sources.iter().position(|&s| s == inp.node) {
                used[si] = true;
            }
        }
    }
    used
}

impl SynthRule {
    /// Package a verified pair. Both graphs are compacted (dense, forward
    /// ordered); the rule's stable name is derived from their canonical
    /// hashes, so identical pairs get identical names across runs.
    ///
    /// Errors if either side is not a single-output pattern, the source
    /// signatures disagree, the rhs is op-free, or the rhs reads a source
    /// the lhs never touches (such a source would be unbound at apply time).
    pub fn new(lhs: &Graph, rhs: &Graph, tier: Tier, shape_generic: bool) -> anyhow::Result<Self> {
        let (lhs, _) = lhs.compact()?;
        let (rhs, _) = rhs.compact()?;
        lhs.validate()?;
        rhs.validate()?;

        let lhs_sources = sources_of(&lhs);
        let rhs_sources = sources_of(&rhs);
        let lhs_ops = ops_of(&lhs);
        let rhs_ops = ops_of(&rhs);
        anyhow::ensure!(!lhs_ops.is_empty() && !rhs_ops.is_empty(), "op-free pattern side");
        anyhow::ensure!(
            lhs_sources.len() == rhs_sources.len(),
            "source count mismatch: {} vs {}",
            lhs_sources.len(),
            rhs_sources.len()
        );
        for (&ls, &rs) in lhs_sources.iter().zip(&rhs_sources) {
            anyhow::ensure!(
                lhs.node(ls).outs[0] == rhs.node(rs).outs[0],
                "source descriptor mismatch at index pair ({:?}, {:?})",
                ls,
                rs
            );
        }
        let lhs_used = used_sources(&lhs, &lhs_sources);
        let rhs_used = used_sources(&rhs, &rhs_sources);
        for (si, (&lu, &ru)) in lhs_used.iter().zip(&rhs_used).enumerate() {
            anyhow::ensure!(
                lu || !ru,
                "rhs reads source {} that the lhs never binds",
                si
            );
        }
        let louts = lhs.output_ids();
        let routs = rhs.output_ids();
        anyhow::ensure!(louts.len() == 1 && routs.len() == 1, "patterns must be single-output");
        anyhow::ensure!(
            lhs.node(louts[0]).outs[0] == rhs.node(routs[0]).outs[0],
            "pattern output descriptors differ"
        );

        // Content-derived stable name: identical (lhs, rhs) pairs produce
        // identical names across runs, machines and serialisation round
        // trips. Leaked because `Rule::name` returns `&'static str` (the
        // search frontier stores it by reference).
        let (hl, hr) = (canonical_hash(&lhs), canonical_hash(&rhs));
        let id = (hl ^ hr.rotate_left(17)).wrapping_mul(0x9E3779B97F4A7C15);
        let name: &'static str =
            Box::leak(format!("synth_{:016x}", id).into_boxed_str());

        let mut kinds: Vec<OpKind> = Vec::new();
        for &id in &lhs_ops {
            let op = lhs.node(id).op.clone();
            if !kinds.contains(&op) {
                kinds.push(op);
            }
        }
        let relevance = OpRelevance::from_fn(move |op| kinds.contains(op));

        Ok(Self {
            name,
            tier,
            shape_generic,
            lhs_out: louts[0],
            rhs_out: routs[0],
            lhs,
            rhs,
            lhs_sources,
            lhs_ops,
            rhs_sources,
            rhs_ops,
            relevance,
        })
    }

    /// The ruleset tier this rule was assigned at synthesis time.
    pub fn tier(&self) -> Tier {
        self.tier
    }

    /// Did the rule verify at non-square probe shapes (true) or only in the
    /// square enumeration regime (false — matches are then restricted to
    /// uniform square bindings)?
    pub fn shape_generic(&self) -> bool {
        self.shape_generic
    }

    /// The matched pattern.
    pub fn lhs(&self) -> &Graph {
        &self.lhs
    }

    /// The replacement pattern.
    pub fn rhs(&self) -> &Graph {
        &self.rhs
    }

    /// Position of `id` within `self.lhs_ops` (pattern op index).
    fn lhs_op_pos(&self, id: NodeId) -> Option<usize> {
        self.lhs_ops.iter().position(|&o| o == id)
    }

    /// Position of `id` within `self.lhs_sources` (source index).
    fn lhs_src_pos(&self, id: NodeId) -> Option<usize> {
        self.lhs_sources.iter().position(|&s| s == id)
    }

    /// Try to extend a partial assignment with `target` for pattern op
    /// `pi`. Returns the source bindings added (for backtracking) or `None`
    /// if the constraints fail.
    fn try_bind(
        &self,
        g: &Graph,
        pi: usize,
        target: NodeId,
        assigned: &[NodeId],
        src_bind: &mut [Option<PortRef>],
    ) -> Option<Vec<usize>> {
        let pat = self.lhs.node(self.lhs_ops[pi]);
        let tgt = g.node(target);
        if tgt.dead || tgt.op != pat.op || tgt.inputs.len() != pat.inputs.len() {
            return None;
        }
        let mut newly_bound = Vec::new();
        for (k, lp) in pat.inputs.iter().enumerate() {
            let tp = tgt.inputs[k];
            if let Some(si) = self.lhs_src_pos(lp.node) {
                match src_bind[si] {
                    Some(p) if p == tp => {}
                    Some(_) => {
                        for &b in &newly_bound {
                            src_bind[b] = None;
                        }
                        return None;
                    }
                    None => {
                        src_bind[si] = Some(tp);
                        newly_bound.push(si);
                    }
                }
            } else {
                // Op-to-op edge: must map to the already-assigned target
                // (pattern is forward-ordered, so the producer has a lower
                // pattern index and is bound).
                let pos = self.lhs_op_pos(lp.node).expect("pattern edge to unknown node");
                debug_assert!(pos < pi);
                if tp.node != assigned[pos] || tp.port != lp.port {
                    for &b in &newly_bound {
                        src_bind[b] = None;
                    }
                    return None;
                }
            }
        }
        Some(newly_bound)
    }

    /// Simulate building the rhs at the bound shapes. Returns the inferred
    /// output descriptor, or `None` if shape inference rejects the rhs.
    fn infer_rhs_out(&self, g: &Graph, src_bind: &[Option<PortRef>]) -> Option<TensorDesc> {
        let mut descs: HashMap<NodeId, TensorDesc> = HashMap::new();
        for (si, &rs) in self.rhs_sources.iter().enumerate() {
            if let Some(p) = src_bind[si] {
                descs.insert(rs, g.out_desc(p).ok()?.clone());
            }
        }
        let mut out = None;
        for &id in &self.rhs_ops {
            let node = self.rhs.node(id);
            let ins: Vec<&TensorDesc> = node
                .inputs
                .iter()
                .map(|p| descs.get(&p.node))
                .collect::<Option<Vec<_>>>()?;
            let inferred = crate::graph::shapes::infer(&node.op, &ins).ok()?;
            if id == self.rhs_out {
                out = Some(inferred[0].clone());
            }
            descs.insert(id, inferred.into_iter().next()?);
        }
        out
    }

    /// Square-regime guard for non-shape-generic rules: every bound source
    /// must be the same `[n, n]` f32 tensor shape the validator covered.
    fn bindings_in_verified_class(&self, g: &Graph, src_bind: &[Option<PortRef>]) -> bool {
        if self.shape_generic {
            return true;
        }
        let mut n: Option<usize> = None;
        for p in src_bind.iter().flatten() {
            let d = match g.out_desc(*p) {
                Ok(d) => d,
                Err(_) => return false,
            };
            if d.shape.len() != 2 || d.shape[0] != d.shape[1] || d.dtype != crate::graph::DType::F32
            {
                return false;
            }
            match n {
                Some(m) if m != d.shape[0] => return false,
                _ => n = Some(d.shape[0]),
            }
        }
        true
    }

    /// Depth-first backtracking match over the pattern ops in index order.
    fn search(
        &self,
        g: &Graph,
        cands: &[Vec<NodeId>],
        pi: usize,
        assigned: &mut Vec<NodeId>,
        src_bind: &mut Vec<Option<PortRef>>,
        out: &mut Vec<Location>,
    ) {
        if pi == self.lhs_ops.len() {
            if !self.bindings_in_verified_class(g, src_bind) {
                return;
            }
            let matched_out = assigned[self.lhs_op_pos(self.lhs_out).unwrap()];
            match self.infer_rhs_out(g, src_bind) {
                Some(d) if d == g.node(matched_out).outs[0] => {
                    out.push(assigned.clone());
                }
                _ => {}
            }
            return;
        }
        for &t in &cands[pi] {
            if assigned.contains(&t) {
                continue; // injective over pattern ops
            }
            if let Some(newly) = self.try_bind(g, pi, t, assigned, src_bind) {
                assigned.push(t);
                self.search(g, cands, pi + 1, assigned, src_bind, out);
                assigned.pop();
                for si in newly {
                    src_bind[si] = None;
                }
            }
        }
    }

    /// Re-derive the source bindings of a previously reported location,
    /// erroring if the graph changed underneath it.
    fn rebind(&self, g: &Graph, loc: &Location) -> anyhow::Result<Vec<Option<PortRef>>> {
        anyhow::ensure!(loc.len() == self.lhs_ops.len(), "location arity mismatch");
        let mut src_bind: Vec<Option<PortRef>> = vec![None; self.lhs_sources.len()];
        for (pi, &t) in loc.iter().enumerate() {
            anyhow::ensure!(t.index() < g.n_slots(), "stale node id {:?}", t);
            anyhow::ensure!(
                self.try_bind(g, pi, t, &loc[..pi], &mut src_bind).is_some(),
                "location no longer matches rule {} at {:?}",
                self.name,
                t
            );
        }
        Ok(src_bind)
    }
}

impl Rule for SynthRule {
    fn name(&self) -> &'static str {
        self.name
    }

    fn find(&self, g: &Graph) -> Vec<Location> {
        // Per-pattern-position candidate lists, ascending target id — the
        // DFS below then emits locations in lexicographic order.
        let mut cands: Vec<Vec<NodeId>> = vec![Vec::new(); self.lhs_ops.len()];
        for id in g.live_ids() {
            let op = &g.node(id).op;
            for (pi, &pid) in self.lhs_ops.iter().enumerate() {
                if *op == self.lhs.node(pid).op {
                    cands[pi].push(id);
                }
            }
        }
        if cands.iter().any(|c| c.is_empty()) {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut assigned = Vec::with_capacity(self.lhs_ops.len());
        let mut src_bind = vec![None; self.lhs_sources.len()];
        self.search(g, &cands, 0, &mut assigned, &mut src_bind, &mut out);
        out
    }

    fn apply(&self, g: &mut Graph, loc: &Location) -> anyhow::Result<()> {
        let src_bind = self.rebind(g, loc)?;
        // Build the rhs on top of the bound sources; shape inference was
        // pre-checked at find time, so `add` cannot fail on a live location.
        let mut new_ids: HashMap<NodeId, NodeId> = HashMap::new();
        for &rid in &self.rhs_ops {
            let node = self.rhs.node(rid);
            let ins: Vec<PortRef> = node
                .inputs
                .iter()
                .map(|p| {
                    if let Some(si) = self.rhs_sources.iter().position(|&s| s == p.node) {
                        src_bind[si].ok_or_else(|| {
                            anyhow::anyhow!("unbound source {} in rule {}", si, self.name)
                        })
                    } else {
                        Ok(PortRef { node: new_ids[&p.node], port: p.port })
                    }
                })
                .collect::<anyhow::Result<_>>()?;
            let nid = g.add(node.op.clone(), &ins)?;
            new_ids.insert(rid, nid);
        }
        let matched_out = loc[self.lhs_op_pos(self.lhs_out).unwrap()];
        splice(g, matched_out, PortRef::of(new_ids[&self.rhs_out]))
        // Interior lhs nodes left without consumers are collected by the
        // caller's DCE pass (`xfer::apply_rule`).
    }

    /// Relevance fingerprint: exactly the operator set of the lhs pattern.
    /// Sound for the incremental matcher because a match's validity is a
    /// function of the matched nodes' operators and input wiring alone
    /// (no consumer-set constraints), and every matched node is listed in
    /// the reported [`Location`].
    fn op_relevant(&self, op: &OpKind) -> bool {
        self.relevance.matches(op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::interp::semantically_equal;

    /// relu(relu(x)) → relu(x), built by hand.
    fn relu_squash() -> SynthRule {
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let r1 = g.add(OpKind::Relu, &[PortRef::of(x)]).unwrap();
        let _r2 = g.add(OpKind::Relu, &[PortRef::of(r1)]).unwrap();
        let lhs = g;
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let _r = g.add(OpKind::Relu, &[PortRef::of(x)]).unwrap();
        let rhs = g;
        SynthRule::new(&lhs, &rhs, Tier::AlwaysSafe, true).unwrap()
    }

    #[test]
    fn name_is_stable_and_content_derived() {
        let a = relu_squash();
        let b = relu_squash();
        assert_eq!(a.name(), b.name());
        assert!(a.name().starts_with("synth_"));
    }

    #[test]
    fn finds_and_applies_on_a_host_graph() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 8]);
        let r1 = b.relu(x).unwrap();
        let r2 = b.relu(r1).unwrap();
        let _t = b.op(OpKind::Tanh, &[r2]).unwrap();
        let g = b.finish();

        let rule = relu_squash();
        let locs = rule.find(&g);
        assert_eq!(locs.len(), 1, "exactly one relu chain");
        let mut g2 = g.clone();
        crate::xfer::apply_rule(&mut g2, &rule, &locs[0]).unwrap();
        assert_eq!(g2.n_ops(), g.n_ops() - 1);
        assert!(semantically_equal(&g, &g2, 3, 7, 1e-5).unwrap());
        // The rewritten graph offers no further sites.
        assert!(rule.find(&g2).is_empty());
    }

    #[test]
    fn relevance_covers_match_nodes_only() {
        let rule = relu_squash();
        assert!(rule.op_relevant(&OpKind::Relu));
        assert!(!rule.op_relevant(&OpKind::Tanh));
        assert!(!rule.op_relevant(&OpKind::Add));
    }

    #[test]
    fn non_shape_generic_rules_match_square_only() {
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let r1 = g.add(OpKind::Relu, &[PortRef::of(x)]).unwrap();
        let _ = g.add(OpKind::Relu, &[PortRef::of(r1)]).unwrap();
        let lhs = g;
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let _ = g.add(OpKind::Relu, &[PortRef::of(x)]).unwrap();
        let rhs = g;
        let rule = SynthRule::new(&lhs, &rhs, Tier::All, false).unwrap();

        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 8]); // rectangular: outside the verified class
        let r1 = b.relu(x).unwrap();
        let _ = b.relu(r1).unwrap();
        assert!(rule.find(&b.finish()).is_empty());

        let mut b = GraphBuilder::new();
        let x = b.input(&[8, 8]); // square: inside
        let r1 = b.relu(x).unwrap();
        let _ = b.relu(r1).unwrap();
        assert_eq!(rule.find(&b.finish()).len(), 1);
    }

    #[test]
    fn rhs_reading_unbound_source_is_rejected() {
        // lhs touches only x; rhs reads y — unbindable at apply time.
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let _y = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let _ = g.add(OpKind::Relu, &[PortRef::of(x)]).unwrap();
        let lhs = g;
        let mut g = Graph::new();
        let _x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let y = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let _ = g.add(OpKind::Relu, &[PortRef::of(y)]).unwrap();
        let rhs = g;
        assert!(SynthRule::new(&lhs, &rhs, Tier::All, true).is_err());
    }

    #[test]
    fn shared_source_pattern_requires_shared_wiring() {
        // lhs add(x, x) must not match add(a, b) with distinct producers.
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let _ = g.add(OpKind::Add, &[PortRef::of(x), PortRef::of(x)]).unwrap();
        let lhs = g;
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let _ = g.add(OpKind::Scale { factor: 2.0 }, &[PortRef::of(x)]).unwrap();
        let rhs = g;
        let rule = SynthRule::new(&lhs, &rhs, Tier::AlwaysSafe, true).unwrap();

        let mut b = GraphBuilder::new();
        let p = b.input(&[4, 4]);
        let q = b.input(&[4, 4]);
        let _ = b.add(p, q).unwrap();
        assert!(rule.find(&b.finish()).is_empty(), "add(p, q) is not add(x, x)");

        let mut b = GraphBuilder::new();
        let p = b.input(&[4, 4]);
        let r = b.relu(p).unwrap();
        let _ = b.add(r, r).unwrap();
        let g = b.finish();
        let locs = rule.find(&g);
        assert_eq!(locs.len(), 1);
        let mut g2 = g.clone();
        crate::xfer::apply_rule(&mut g2, &rule, &locs[0]).unwrap();
        assert!(semantically_equal(&g, &g2, 2, 3, 1e-5).unwrap());
    }
}
