//! On-disk ruleset format: a JSON document embedding each rule's lhs/rhs
//! pattern in the crate's ONNX-style graph serialisation.
//!
//! Writing goes through `util::json`'s `BTreeMap`-backed objects and the
//! deterministic pretty-printer, so a fixed rule list always serialises to
//! bit-identical bytes — the property the synthesis-determinism test pins.
//!
//! Loading re-derives each rule's content-addressed name from the imported
//! pattern pair and cross-checks it against the stored one, so a corrupted
//! or hand-edited file fails loudly instead of silently shifting the
//! `RuleSet::fingerprint` the search cache keys on.

use crate::graph::onnx;
use crate::util::json::{parse, Json};

use super::rule::SynthRule;
use super::{SynthConfig, Tier};

/// Magic format tag (first field of every ruleset file).
pub const FORMAT: &str = "rlflow-ruleset";
/// Current format version.
pub const VERSION: usize = 1;

/// Serialise synthesised rules (plus the config that produced them) to the
/// on-disk JSON document.
pub fn rules_to_json(rules: &[SynthRule], cfg: &SynthConfig) -> anyhow::Result<Json> {
    let mut doc = Json::obj();
    doc.set("format", Json::Str(FORMAT.into()));
    doc.set("version", Json::Num(VERSION as f64));
    doc.set("alphabet", Json::Str(cfg.alphabet.clone()));
    doc.set("n_inputs", Json::Num(cfg.n_inputs as f64));
    doc.set("max_ops", Json::Num(cfg.max_ops as f64));
    doc.set("seed", Json::Num(cfg.seed as f64));
    doc.set("tier", Json::Str(cfg.tier.as_str().into()));
    let mut arr = Vec::with_capacity(rules.len());
    for r in rules {
        let mut rj = Json::obj();
        rj.set("name", Json::Str(r.name().into()));
        rj.set("tier", Json::Str(r.tier().as_str().into()));
        rj.set("shape_generic", Json::Bool(r.shape_generic()));
        rj.set("lhs", onnx::export(r.lhs(), &format!("{}_lhs", r.name()))?);
        rj.set("rhs", onnx::export(r.rhs(), &format!("{}_rhs", r.name()))?);
        arr.push(rj);
    }
    doc.set("rules", Json::Arr(arr));
    Ok(doc)
}

/// Parse a ruleset document back into [`SynthRule`]s, re-verifying each
/// rule's content-derived name.
pub fn rules_from_json(doc: &Json) -> anyhow::Result<Vec<SynthRule>> {
    anyhow::ensure!(
        doc.get("format")?.as_str()? == FORMAT,
        "not a {} document",
        FORMAT
    );
    let version = doc.get("version")?.as_usize()?;
    anyhow::ensure!(version == VERSION, "unsupported ruleset version {}", version);
    let mut rules = Vec::new();
    for rj in doc.get("rules")?.as_arr()? {
        let name = rj.get("name")?.as_str()?;
        let tier = Tier::parse(rj.get("tier")?.as_str()?)?;
        let shape_generic = rj.get("shape_generic")?.as_bool()?;
        let lhs = onnx::import(rj.get("lhs")?)?;
        let rhs = onnx::import(rj.get("rhs")?)?;
        let rule = SynthRule::new(&lhs, &rhs, tier, shape_generic)?;
        anyhow::ensure!(
            rule.name() == name,
            "ruleset integrity: stored name {} does not match content hash {}",
            name,
            rule.name()
        );
        rules.push(rule);
    }
    Ok(rules)
}

/// Write a ruleset file (deterministic bytes for a fixed rule list).
pub fn save_rules<P: AsRef<std::path::Path>>(
    path: P,
    rules: &[SynthRule],
    cfg: &SynthConfig,
) -> anyhow::Result<()> {
    let doc = rules_to_json(rules, cfg)?;
    std::fs::write(path, doc.to_string_pretty())?;
    Ok(())
}

/// Load a ruleset file written by [`save_rules`].
pub fn load_rules<P: AsRef<std::path::Path>>(path: P) -> anyhow::Result<Vec<SynthRule>> {
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading ruleset {}: {}", path.as_ref().display(), e))?;
    rules_from_json(&parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_preserves_rules_and_bytes() {
        let cfg = SynthConfig {
            alphabet: "ewise,act,shape,scale".into(),
            tier: Tier::All,
            ..SynthConfig::default()
        };
        let out = super::super::synthesise(&cfg).unwrap();
        assert!(!out.rules.is_empty());
        let doc = rules_to_json(&out.rules, &cfg).unwrap();
        let bytes = doc.to_string_pretty();
        let back = rules_from_json(&parse(&bytes).unwrap()).unwrap();
        assert_eq!(back.len(), out.rules.len());
        for (a, b) in out.rules.iter().zip(&back) {
            assert_eq!(a.name(), b.name());
            assert_eq!(a.tier(), b.tier());
            assert_eq!(a.shape_generic(), b.shape_generic());
        }
        // Serialising the reloaded rules reproduces the exact bytes.
        let bytes2 = rules_to_json(&back, &cfg).unwrap().to_string_pretty();
        assert_eq!(bytes, bytes2);
    }

    #[test]
    fn tampered_name_is_rejected() {
        let cfg = SynthConfig {
            alphabet: "act".into(),
            tier: Tier::All,
            ..SynthConfig::default()
        };
        let out = super::super::synthesise(&cfg).unwrap();
        assert!(!out.rules.is_empty());
        let doc = rules_to_json(&out.rules, &cfg).unwrap();
        let text = doc.to_string_pretty().replace("synth_", "synth0");
        assert!(rules_from_json(&parse(&text).unwrap()).is_err());
    }
}
