//! Substitution engine (§3.2): rules, matcher, application, generation.
//!
//! A [`Rule`] knows how to *find* its applicable locations in a graph and
//! how to *apply* itself at one of them. Locations are ordered lists of
//! anchor [`NodeId`]s — the environment exposes `min(matches, MAX_LOCS)`
//! of them to the agent as the location action (§3.1.3).
//!
//! Weight-only arithmetic introduced by rewrites (concatenated kernels,
//! BN-folded weights, composed 1x1 convs) stays in the graph as ordinary
//! ops over `Weight` sources: the interpreter then verifies substitutions
//! *exactly*, while the cost model constant-folds weight-only subtrees to
//! zero runtime (they are precomputed at model-load time, as TASO does).

pub mod apply;
pub mod generator;
pub mod library;
pub mod library_ext;
pub mod matcher;
pub mod synth;

pub use apply::{ApplyReport, DirtyRegion};

use crate::graph::{Graph, NodeId, OpKind};

/// Anchor nodes identifying one applicable site of a rule.
pub type Location = Vec<NodeId>;

pub trait Rule: Send + Sync {
    /// Stable, unique rule name (also its display label in Fig. 10).
    fn name(&self) -> &'static str;

    /// All sites where this rule can fire, in deterministic order.
    fn find(&self, g: &Graph) -> Vec<Location>;

    /// Rewrite the graph at `loc`. `loc` must come from a `find` on the
    /// *current* graph state. Implementations must leave the graph valid.
    fn apply(&self, g: &mut Graph, loc: &Location) -> anyhow::Result<()>;

    /// Could a node with this operator participate in *any* match of this
    /// rule? Consumed by the incremental match maintenance
    /// (`env::incremental`): after a rewrite, a rule is only re-matched
    /// when some node in the dirty region is relevant to it (or one of its
    /// cached locations was touched). The default is the conservative
    /// "yes" — such rules re-match after every rewrite. Implementations
    /// tightening this must guarantee two things: (a) every node whose
    /// local state (operator, inputs, consumer set) a match's validity
    /// depends on is listed in the reported [`Location`], and (b) every
    /// node of every possible match satisfies the relevance test.
    fn op_relevant(&self, op: &OpKind) -> bool {
        let _ = op;
        true
    }
}

/// Apply a rule site and run the post-rewrite housekeeping every caller
/// needs: dead-code elimination plus (debug) validation. Returns the
/// [`ApplyReport`] live-set diff so callers can re-cost incrementally
/// (`CostModel::delta_runtime_ms`) instead of walking the whole graph.
pub fn apply_rule(g: &mut Graph, rule: &dyn Rule, loc: &Location) -> anyhow::Result<ApplyReport> {
    let prev_slots = g.n_slots();
    let live_before: Vec<bool> = g.nodes.iter().map(|n| !n.dead).collect();
    rule.apply(g, loc)?;
    g.dce();
    debug_assert!(g.validate().is_ok(), "rule {} broke the graph", rule.name());
    Ok(ApplyReport::diff(g, prev_slots, &live_before))
}

/// A rule set with stable slot indices (the agent's xfer action space).
pub struct RuleSet {
    pub rules: Vec<Box<dyn Rule>>,
}

impl RuleSet {
    pub fn new(rules: Vec<Box<dyn Rule>>) -> Self {
        let mut seen = std::collections::HashSet::new();
        for r in &rules {
            assert!(seen.insert(r.name()), "duplicate rule name {}", r.name());
        }
        Self { rules }
    }

    pub fn len(&self) -> usize {
        self.rules.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    pub fn get(&self, idx: usize) -> Option<&dyn Rule> {
        self.rules.get(idx).map(|b| b.as_ref())
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.rules.iter().position(|r| r.name() == name)
    }

    /// Total number of applicable sites across all rules (Table 1's
    /// "Substitutions" column).
    pub fn count_matches(&self, g: &Graph) -> usize {
        self.rules.iter().map(|r| r.find(g).len()).sum()
    }

    /// Order-sensitive fingerprint of the rule vocabulary: the rule names
    /// at their slot indices. Rule names are unique (enforced by
    /// [`RuleSet::new`]) and slot order is the agent's action space, so two
    /// equal fingerprints mean the same searches and the same action
    /// numbering — what the persistent `search::SearchCache` keys on.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xCBF29CE484222325;
        for r in &self.rules {
            for b in r.name().bytes() {
                h = (h ^ b as u64).wrapping_mul(0x100000001B3);
            }
            h = h.rotate_left(7) ^ 0x2D;
        }
        h
    }
}
