//! Extended rule families: merged fused-conv branches, LHS transpose
//! absorption, pooling composition, transpose distribution over
//! elementwise ops, and RHS scale hoisting. Registered after the core
//! library; together they fill the artifact's 48 xfer slots with
//! genuinely distinct rewrites (the paper's agent chooses among >100).

use crate::graph::{Activation, NodeId, OpKind, PadMode, PortRef};
#[cfg(test)]
use crate::graph::Graph;
use crate::pred;

use super::apply::{live_op, splice, splice_port};
use super::library::rule_rel;
use super::matcher::{find_chains, find_siblings, sorted_consumers_vec};
use super::Rule;

/// Merge two parallel `ConvBias` branches with identical attributes and
/// weight shapes (arises after BN folding in ResNet/Inception blocks).
pub fn merge_convbias_siblings() -> Box<dyn Rule> {
    rule_rel(
        "merge_convbias2",
        &[|op| matches!(op, OpKind::ConvBias { .. })],
        |g| {
            find_siblings(g, &pred!(cb: OpKind::ConvBias { .. }), 2)
                .into_iter()
                .filter(|pair| {
                    let (a, b) = (g.node(pair[0]), g.node(pair[1]));
                    a.op == b.op
                        && a.inputs[0] == b.inputs[0]
                        && match (g.out_desc(a.inputs[1]), g.out_desc(b.inputs[1])) {
                            (Ok(da), Ok(db)) => da.shape == db.shape,
                            _ => false,
                        }
                })
                .collect()
        },
        |g, loc| {
            let (a_id, b_id) = (loc[0], loc[1]);
            let op = live_op(g, a_id)?.clone();
            anyhow::ensure!(&op == live_op(g, b_id)?, "merge_convbias2: attrs differ");
            let (x, wa, ba) = (
                g.node(a_id).inputs[0],
                g.node(a_id).inputs[1],
                g.node(a_id).inputs[2],
            );
            let (wb, bb) = (g.node(b_id).inputs[1], g.node(b_id).inputs[2]);
            anyhow::ensure!(g.node(b_id).inputs[0] == x, "merge_convbias2: inputs differ");
            let wcat = g.add(OpKind::Concat { axis: 0 }, &[wa, wb])?;
            let bcat = g.add(OpKind::Concat { axis: 0 }, &[ba, bb])?;
            let conv = g.add(op, &[x, PortRef::of(wcat), PortRef::of(bcat)])?;
            let split = g.add(OpKind::Split { axis: 1, parts: 2 }, &[PortRef::of(conv)])?;
            splice_port(g, PortRef::of(a_id), PortRef { node: split, port: 0 })?;
            splice_port(g, PortRef::of(b_id), PortRef { node: split, port: 1 })?;
            g.kill(a_id);
            g.kill(b_id);
            Ok(())
        },
    )
}

/// matmul(transpose(a), b) => matmul{trans_a}(a, b) for last-two-swap
/// transposes feeding the LHS exclusively.
pub fn absorb_transpose_lhs() -> Box<dyn Rule> {
    rule_rel(
        "absorb_transpose_lhs",
        &[
            |op| matches!(op, OpKind::Transpose { .. }),
            |op| matches!(op, OpKind::MatMul { trans_a: false, .. }),
        ],
        |g| {
            let cons = sorted_consumers_vec(g);
            let mut out = Vec::new();
            for id in g.live_ids() {
                let n = g.node(id);
                let OpKind::MatMul { trans_a: false, trans_b, act } = n.op else { continue };
                let _ = (trans_b, act);
                let lhs = n.inputs[0];
                if lhs.port != 0 {
                    continue;
                }
                let OpKind::Transpose { perm } = &g.node(lhs.node).op else { continue };
                let r = perm.len();
                if r < 2 {
                    continue;
                }
                let mut want: Vec<usize> = (0..r).collect();
                want.swap(r - 2, r - 1);
                if perm != &want || cons[lhs.node.index()].len() != 1 {
                    continue;
                }
                out.push(vec![lhs.node, id]);
            }
            out
        },
        |g, loc| {
            let (t_id, mm_id) = (loc[0], loc[1]);
            let OpKind::MatMul { trans_a: false, trans_b, act } = *live_op(g, mm_id)? else {
                anyhow::bail!("absorb_transpose_lhs: stale matmul")
            };
            let a_src = g.node(t_id).inputs[0];
            let b = g.node(mm_id).inputs[1];
            let mm = g.add(OpKind::MatMul { trans_a: true, trans_b, act }, &[a_src, b])?;
            splice(g, mm_id, PortRef::of(mm))?;
            g.kill(t_id);
            Ok(())
        },
    )
}

/// Compose two stacked max-pools (VALID padding): maxpool(k1, s1) then
/// maxpool(k2, s2) == maxpool(k1 + (k2-1)*s1, s1*s2). Exact for max.
pub fn compose_maxpools() -> Box<dyn Rule> {
    rule_rel(
        "compose_maxpool2",
        &[|op| matches!(op, OpKind::MaxPool { pad: PadMode::Valid, .. })],
        |g| {
            find_chains(
                g,
                &[
                    pred!(p1: OpKind::MaxPool { pad: PadMode::Valid, .. }),
                    pred!(p2: OpKind::MaxPool { pad: PadMode::Valid, .. }),
                ],
            )
        },
        |g, loc| {
            let (p1, p2) = (loc[0], loc[1]);
            let OpKind::MaxPool { k: k1, stride: s1, pad: PadMode::Valid } = *live_op(g, p1)? else {
                anyhow::bail!("compose_maxpool2: stale")
            };
            let OpKind::MaxPool { k: k2, stride: s2, pad: PadMode::Valid } = *live_op(g, p2)? else {
                anyhow::bail!("compose_maxpool2: stale")
            };
            let x = g.node(p1).inputs[0];
            let fused = g.add(
                OpKind::MaxPool { k: k1 + (k2 - 1) * s1, stride: s1 * s2, pad: PadMode::Valid },
                &[x],
            )?;
            // Output shapes must agree exactly (guaranteed for VALID).
            anyhow::ensure!(
                g.node(fused).outs[0] == g.node(p2).outs[0],
                "compose_maxpool2: shape drift"
            );
            splice(g, p2, PortRef::of(fused))?;
            g.kill(p1);
            Ok(())
        },
    )
}

/// transpose(add(a, b)) => add(transpose(a), transpose(b)) — distributes
/// the data movement into the branches where it may cancel against
/// existing transposes. Requires a non-broadcast add.
pub fn push_transpose_through_add() -> Box<dyn Rule> {
    rule_rel(
        "push_transpose_add",
        &[
            |op| matches!(op, OpKind::Add),
            |op| matches!(op, OpKind::Transpose { .. }),
        ],
        |g| {
            find_chains(g, &[pred!(a: OpKind::Add), pred!(t: OpKind::Transpose { .. })])
                .into_iter()
                .filter(|loc| {
                    let add = g.node(loc[0]);
                    match (g.out_desc(add.inputs[0]), g.out_desc(add.inputs[1])) {
                        (Ok(a), Ok(b)) => a.shape == b.shape,
                        _ => false,
                    }
                })
                .collect()
        },
        |g, loc| {
            let (add_id, t_id) = (loc[0], loc[1]);
            let OpKind::Transpose { perm } = live_op(g, t_id)?.clone() else {
                anyhow::bail!("push_transpose_add: stale")
            };
            let (a, b) = (g.node(add_id).inputs[0], g.node(add_id).inputs[1]);
            let ta = g.add(OpKind::Transpose { perm: perm.clone() }, &[a])?;
            let tb = g.add(OpKind::Transpose { perm }, &[b])?;
            let sum = g.add(OpKind::Add, &[PortRef::of(ta), PortRef::of(tb)])?;
            splice(g, t_id, PortRef::of(sum))?;
            g.kill(add_id);
            Ok(())
        },
    )
}

/// Inverse: add(transpose(a), transpose(b)) with equal perms => transpose(add).
pub fn pull_transpose_out_of_add() -> Box<dyn Rule> {
    rule_rel(
        "pull_transpose_add",
        &[
            |op| matches!(op, OpKind::Transpose { .. }),
            |op| matches!(op, OpKind::Add),
        ],
        |g| {
            let cons = sorted_consumers_vec(g);
            let mut out = Vec::new();
            for id in g.live_ids() {
                let n = g.node(id);
                if !matches!(n.op, OpKind::Add) || n.inputs.len() != 2 {
                    continue;
                }
                let (pa, pb) = (n.inputs[0], n.inputs[1]);
                let (ta, tb) = (g.node(pa.node), g.node(pb.node));
                let (OpKind::Transpose { perm: qa }, OpKind::Transpose { perm: qb }) = (&ta.op, &tb.op) else {
                    continue;
                };
                if qa != qb || pa.node == pb.node {
                    continue;
                }
                let sole = |t: NodeId| cons[t.index()].len() == 1;
                if sole(pa.node) && sole(pb.node) {
                    out.push(vec![pa.node, pb.node, id]);
                }
            }
            out
        },
        |g, loc| {
            let (ta, tb, add_id) = (loc[0], loc[1], loc[2]);
            let OpKind::Transpose { perm } = live_op(g, ta)?.clone() else {
                anyhow::bail!("pull_transpose_add: stale")
            };
            let a_src = g.node(ta).inputs[0];
            let b_src = g.node(tb).inputs[0];
            let sum = g.add(OpKind::Add, &[a_src, b_src])?;
            let t = g.add(OpKind::Transpose { perm }, &[PortRef::of(sum)])?;
            splice(g, add_id, PortRef::of(t))?;
            g.kill(ta);
            g.kill(tb);
            Ok(())
        },
    )
}

/// matmul(a, scale(b)) => scale(matmul(a, b)) — RHS counterpart of
/// hoist_scale_matmul (the chain matcher only follows first inputs).
pub fn hoist_scale_matmul_rhs() -> Box<dyn Rule> {
    rule_rel(
        "hoist_scale_matmul_rhs",
        &[
            |op| matches!(op, OpKind::Scale { .. }),
            |op| matches!(op, OpKind::MatMul { act: Activation::None, .. }),
        ],
        |g| {
            let cons = sorted_consumers_vec(g);
            let mut out = Vec::new();
            for id in g.live_ids() {
                let n = g.node(id);
                let OpKind::MatMul { act: Activation::None, .. } = n.op else { continue };
                let rhs = n.inputs[1];
                if !matches!(g.node(rhs.node).op, OpKind::Scale { .. }) {
                    continue;
                }
                if cons[rhs.node.index()].len() != 1 {
                    continue;
                }
                out.push(vec![rhs.node, id]);
            }
            out
        },
        |g, loc| {
            let (s_id, mm_id) = (loc[0], loc[1]);
            let scale_op = live_op(g, s_id)?.clone();
            let mm_op = live_op(g, mm_id)?.clone();
            let a = g.node(mm_id).inputs[0];
            let b_src = g.node(s_id).inputs[0];
            let mm = g.add(mm_op, &[a, b_src])?;
            let sc = g.add(scale_op, &[PortRef::of(mm)])?;
            splice(g, mm_id, PortRef::of(sc))?;
            g.kill(s_id);
            Ok(())
        },
    )
}

/// scale(scale(x)) => scale(x) with the product factor.
pub fn compose_scales() -> Box<dyn Rule> {
    rule_rel(
        "compose_scale2",
        &[|op| matches!(op, OpKind::Scale { .. })],
        |g| find_chains(g, &[pred!(a: OpKind::Scale { .. }), pred!(b: OpKind::Scale { .. })]),
        |g, loc| {
            let (s1, s2) = (loc[0], loc[1]);
            let OpKind::Scale { factor: f1 } = *live_op(g, s1)? else {
                anyhow::bail!("compose_scale2: stale")
            };
            let OpKind::Scale { factor: f2 } = *live_op(g, s2)? else {
                anyhow::bail!("compose_scale2: stale")
            };
            let x = g.node(s1).inputs[0];
            let s = g.add(OpKind::Scale { factor: f1 * f2 }, &[x])?;
            splice(g, s2, PortRef::of(s))?;
            g.kill(s1);
            Ok(())
        },
    )
}

/// mul(x, w) + add(*, b) with per-last-axis weight/bias vectors => a
/// scale-shift pair is recognisable as an (inference-time) BatchNorm when
/// x is NCHW and w/b broadcast over channels. Kept general: fuses the two
/// elementwise passes into one AddN-style op is not expressible, so this
/// rule instead *reassociates* mul-by-weight chains:
/// mul(mul(x, a), b) => mul(x, a*b) when a, b are weight-constant.
pub fn compose_weight_muls() -> Box<dyn Rule> {
    rule_rel(
        "compose_mul2",
        &[|op| matches!(op, OpKind::Mul)],
        |g| {
            find_chains(g, &[pred!(a: OpKind::Mul), pred!(b: OpKind::Mul)])
                .into_iter()
                .filter(|loc| {
                    // Second operands of both muls must be equal-shaped so
                    // the combined constant keeps broadcasting semantics.
                    let m1 = g.node(loc[0]);
                    let m2 = g.node(loc[1]);
                    match (g.out_desc(m1.inputs[1]), g.out_desc(m2.inputs[1])) {
                        (Ok(a), Ok(b)) => a.shape == b.shape,
                        _ => false,
                    }
                })
                .collect()
        },
        |g, loc| {
            let (m1, m2) = (loc[0], loc[1]);
            let x = g.node(m1).inputs[0];
            let a = g.node(m1).inputs[1];
            let b = g.node(m2).inputs[1];
            let ab = g.add(OpKind::Mul, &[a, b])?;
            let out = g.add(OpKind::Mul, &[x, PortRef::of(ab)])?;
            splice(g, m2, PortRef::of(out))?;
            g.kill(m1);
            Ok(())
        },
    )
}

/// All extended rules in registration order.
pub fn extended_rules() -> Vec<Box<dyn Rule>> {
    vec![
        merge_convbias_siblings(),
        absorb_transpose_lhs(),
        compose_maxpools(),
        push_transpose_through_add(),
        pull_transpose_out_of_add(),
        hoist_scale_matmul_rhs(),
        compose_scales(),
        compose_weight_muls(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::interp::semantically_equal;
    use crate::xfer::apply_rule;
    use crate::xfer::library::standard_library;

    fn check_rule_on(g: &Graph, rule_name: &str) -> usize {
        let lib = standard_library();
        let idx = lib.index_of(rule_name).unwrap_or_else(|| panic!("no rule {rule_name}"));
        let rule = lib.get(idx).unwrap();
        let locs = rule.find(g);
        for loc in &locs {
            let mut g2 = g.clone();
            apply_rule(&mut g2, rule, loc).unwrap();
            g2.validate().unwrap();
            assert!(
                semantically_equal(g, &g2, 2, 4242, 2e-3).unwrap(),
                "{rule_name} at {:?} changed semantics",
                loc
            );
        }
        locs.len()
    }

    #[test]
    fn merge_convbias_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 6, 6]);
        for _ in 0..2 {
            let w = b.weight(&[4, 3, 3, 3]);
            let bias = b.weight(&[4]);
            let cb = b
                .op(
                    OpKind::ConvBias { stride: 1, pad: PadMode::Same, act: Activation::Relu },
                    &[x, w, bias],
                )
                .unwrap();
            b.relu(cb).unwrap();
        }
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "merge_convbias2"), 1);
    }

    #[test]
    fn absorb_transpose_lhs_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let a = b.input(&[4, 2]);
        let c = b.input(&[4, 3]);
        let at = b.transpose(a, &[1, 0]).unwrap();
        let _ = b
            .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[at, c])
            .unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "absorb_transpose_lhs"), 1);
    }

    #[test]
    fn compose_maxpools_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 2, 16, 16]);
        let p1 = b
            .op(OpKind::MaxPool { k: 2, stride: 2, pad: PadMode::Valid }, &[x])
            .unwrap();
        let _ = b
            .op(OpKind::MaxPool { k: 2, stride: 2, pad: PadMode::Valid }, &[p1])
            .unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "compose_maxpool2"), 1);
    }

    #[test]
    fn transpose_add_distribution_round_trip() {
        use crate::graph::canonical_hash;
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 3, 4]);
        let y = b.input(&[2, 3, 4]);
        let s = b.add(x, y).unwrap();
        let _ = b.transpose(s, &[0, 2, 1]).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "push_transpose_add"), 1);

        let lib = standard_library();
        let push = lib.get(lib.index_of("push_transpose_add").unwrap()).unwrap();
        let pull = lib.get(lib.index_of("pull_transpose_add").unwrap()).unwrap();
        let mut g2 = g.clone();
        let loc = push.find(&g2)[0].clone();
        apply_rule(&mut g2, push, &loc).unwrap();
        assert_eq!(check_rule_on(&g2, "pull_transpose_add"), 1);
        let loc_b = pull.find(&g2)[0].clone();
        apply_rule(&mut g2, pull, &loc_b).unwrap();
        assert_eq!(canonical_hash(&g), canonical_hash(&g2));
    }

    #[test]
    fn scale_rules_preserve_semantics() {
        let mut b = GraphBuilder::new();
        let a = b.input(&[2, 4]);
        let w = b.weight(&[4, 3]);
        let sb = b.op(OpKind::Scale { factor: 0.5 }, &[w]).unwrap();
        let _ = b
            .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[a, sb])
            .unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "hoist_scale_matmul_rhs"), 1);

        let mut b2 = GraphBuilder::new();
        let a2 = b2.input(&[2, 4]);
        let s1 = b2.op(OpKind::Scale { factor: 2.0 }, &[a2]).unwrap();
        let _ = b2.op(OpKind::Scale { factor: 0.25 }, &[s1]).unwrap();
        let g2 = b2.finish();
        assert_eq!(check_rule_on(&g2, "compose_scale2"), 1);
    }

    #[test]
    fn compose_mul_preserves_semantics() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 8]);
        let w1 = b.weight(&[8]);
        let w2 = b.weight(&[8]);
        let m1 = b.op(OpKind::Mul, &[x, w1]).unwrap();
        let _ = b.op(OpKind::Mul, &[m1, w2]).unwrap();
        let g = b.finish();
        assert_eq!(check_rule_on(&g, "compose_mul2"), 1);
    }

    #[test]
    fn library_fits_slot_budget() {
        let lib = standard_library();
        assert!(lib.len() <= 48, "library ({}) exceeds artifact slots", lib.len());
        assert!(lib.len() >= 40, "library ({}) thinner than expected", lib.len());
    }
}
