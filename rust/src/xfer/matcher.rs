//! Structural pattern matching primitives.
//!
//! Every library rule matches one of two shapes, so the matcher exposes two
//! workhorses instead of a fully general (NP-hard) isomorphism search:
//!
//!  * [`find_chains`] — a linear chain `p0 -> p1 -> ... -> pk` where each
//!    interior node's *only* consumer is the next chain element (so the
//!    chain can be deleted wholesale after replacement);
//!  * [`find_siblings`] — `k` distinct nodes matching a predicate that all
//!    read the *same* tensor (parallel branches to merge).
//!
//! Both run in O(nodes * pattern) with deterministic output order, which
//! the environment relies on for stable location indices (§3.1.3).

use std::collections::HashMap;

use crate::graph::{Graph, NodeId, OpKind, PortRef};

/// Operator predicate for one pattern position.
pub struct OpPred {
    pub label: &'static str,
    pub test: fn(&OpKind) -> bool,
}

impl OpPred {
    pub fn exact_name(label: &'static str, test: fn(&OpKind) -> bool) -> Self {
        Self { label, test }
    }
}

/// Convenience macro: `pred!(relu: OpKind::Relu)` or with a guard.
#[macro_export]
macro_rules! pred {
    ($label:ident : $($pat:tt)+) => {
        $crate::xfer::matcher::OpPred {
            label: stringify!($label),
            test: |op| matches!(op, $($pat)+),
        }
    };
}

/// consumers map with deterministic ordering (by consumer id, then slot).
///
/// HashMap form for cold callers; the matcher and rule-library hot paths
/// use the dense [`sorted_consumers_vec`] (the arena-indexed lists come
/// out of graph construction already in `(consumer, slot)` order).
pub fn sorted_consumers(g: &Graph) -> HashMap<NodeId, Vec<(NodeId, usize)>> {
    let mut map = g.consumers();
    for v in map.values_mut() {
        v.sort();
    }
    map
}

/// Dense consumer lists indexed by `NodeId::index`, each sorted by
/// `(consumer id, slot)` — the allocation-light form of
/// [`sorted_consumers`] the per-step matcher hot path uses.
pub fn sorted_consumers_vec(g: &Graph) -> Vec<Vec<(NodeId, usize)>> {
    let cons = g.consumers_vec();
    debug_assert!(cons.iter().all(|v| v.windows(2).all(|w| w[0] <= w[1])));
    cons
}

/// Does `id` have exactly one consumer, and is it `next` reading port 0?
fn sole_consumer_is(cons: &[Vec<(NodeId, usize)>], id: NodeId, next: NodeId) -> bool {
    let v = &cons[id.index()];
    v.len() == 1 && v[0].0 == next
}

/// Find all chains `[n0, n1, ..., nk]` with `ni -> ni+1` dataflow where
/// `ni+1` reads `ni` as its **first** input, every interior node has a
/// single output port in use and a single consumer. Output order follows
/// node-id order of the chain head.
pub fn find_chains(g: &Graph, preds: &[OpPred]) -> Vec<Vec<NodeId>> {
    assert!(preds.len() >= 2, "chains need at least two positions");
    let cons = sorted_consumers_vec(g);
    let mut out = Vec::new();
    for head in g.live_ids() {
        if !(preds[0].test)(&g.node(head).op) {
            continue;
        }
        let mut chain = vec![head];
        let mut ok = true;
        for pred in &preds[1..] {
            let cur = *chain.last().unwrap();
            // The follower must read `cur` (port 0 of it) as first input.
            let next = match &cons[cur.index()] {
                v if v.len() == 1 => v[0].0,
                _ => {
                    ok = false;
                    break;
                }
            };
            let reads_first = g
                .node(next)
                .inputs
                .first()
                .is_some_and(|p| p.node == cur && p.port == 0);
            if !reads_first || !(pred.test)(&g.node(next).op) || !sole_consumer_is(&cons, cur, next)
            {
                ok = false;
                break;
            }
            chain.push(next);
        }
        if ok {
            out.push(chain);
        }
    }
    out
}

/// Find unordered groups of exactly `k` distinct nodes satisfying `pred`
/// that all read the same producer port as their **first** input. Groups
/// are emitted as sorted node-id lists; each combination appears once.
pub fn find_siblings(g: &Graph, pred: &OpPred, k: usize) -> Vec<Vec<NodeId>> {
    let mut by_src: HashMap<PortRef, Vec<NodeId>> = HashMap::new();
    for id in g.live_ids() {
        let node = g.node(id);
        if !(pred.test)(&node.op) {
            continue;
        }
        if let Some(first) = node.inputs.first() {
            by_src.entry(*first).or_default().push(id);
        }
    }
    let mut srcs: Vec<PortRef> = by_src.keys().copied().collect();
    srcs.sort_by_key(|p| (p.node, p.port));
    let mut out = Vec::new();
    for src in srcs {
        let mut sibs = by_src.remove(&src).unwrap();
        sibs.sort();
        if sibs.len() < k {
            continue;
        }
        // Enumerate k-combinations in lexicographic order (bounded: sibling
        // groups in real graphs are small).
        combinations(&sibs, k, &mut out);
    }
    out
}

fn combinations(items: &[NodeId], k: usize, out: &mut Vec<Vec<NodeId>>) {
    let n = items.len();
    if k > n {
        return;
    }
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        out.push(idx.iter().map(|&i| items[i]).collect());
        // Advance combination.
        let mut i = k;
        loop {
            if i == 0 {
                return;
            }
            i -= 1;
            if idx[i] != i + n - k {
                break;
            }
            if i == 0 {
                return;
            }
        }
        idx[i] += 1;
        for j in i + 1..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// Is every consumer of `id` within `allowed`? (Safe-deletion check for
/// interior nodes of a match.)
pub fn consumers_within(g: &Graph, id: NodeId, allowed: &[NodeId]) -> bool {
    g.consumers_vec()[id.index()].iter().all(|(c, _)| allowed.contains(c))
}

/// Operator fingerprint of a rule's pattern: the union of the op
/// predicates any position of the pattern can bind.
///
/// This is the sound "could this node participate in *any* match of the
/// rule?" query the incremental environment (`env::incremental`) uses to
/// skip re-matching: a rewrite can only create a match that contains a
/// node whose local state (operator, inputs, consumer set) it changed, and
/// that node's operator must satisfy one of these predicates. Rules whose
/// match validity depends on nodes *outside* their reported [`Location`]
/// and relevance set must not declare one (they fall back to re-matching
/// after every rewrite).
///
/// [`Location`]: crate::xfer::Location
pub struct OpRelevance {
    test: Box<dyn Fn(&OpKind) -> bool + Send + Sync>,
}

impl OpRelevance {
    /// Union of position predicates (the common case: one per pattern
    /// position, e.g. the `pred!` tests handed to [`find_chains`]).
    pub fn of(tests: &[fn(&OpKind) -> bool]) -> Self {
        let tests = tests.to_vec();
        Self::from_fn(move |op| tests.iter().any(|t| t(op)))
    }

    /// Arbitrary predicate (rules parameterised at construction time).
    pub fn from_fn(f: impl Fn(&OpKind) -> bool + Send + Sync + 'static) -> Self {
        Self { test: Box::new(f) }
    }

    /// Could a node with this operator appear in a match?
    pub fn matches(&self, op: &OpKind) -> bool {
        (self.test)(op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder, PadMode};

    #[test]
    fn chain_conv_relu_found() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        let g = b.finish();
        let chains = find_chains(
            &g,
            &[
                pred!(conv: OpKind::Conv2d { act: Activation::None, .. }),
                pred!(relu: OpKind::Relu),
            ],
        );
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].len(), 2);
    }

    #[test]
    fn chain_requires_single_consumer() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        let _ = b.op(OpKind::Tanh, &[c]).unwrap(); // second consumer of conv
        let g = b.finish();
        let chains = find_chains(
            &g,
            &[
                pred!(conv: OpKind::Conv2d { .. }),
                pred!(relu: OpKind::Relu),
            ],
        );
        assert!(chains.is_empty());
    }

    #[test]
    fn siblings_shared_input() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16]);
        let _ = b.linear(x, 8, Activation::None).unwrap();
        let _ = b.linear(x, 8, Activation::None).unwrap();
        let _ = b.linear(x, 8, Activation::None).unwrap();
        let g = b.finish();
        let pairs = find_siblings(&g, &pred!(lin: OpKind::Linear { .. }), 2);
        assert_eq!(pairs.len(), 3); // C(3,2)
        let triples = find_siblings(&g, &pred!(lin: OpKind::Linear { .. }), 3);
        assert_eq!(triples.len(), 1);
    }

    #[test]
    fn siblings_require_same_source() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16]);
        let y = b.input(&[1, 16]);
        let _ = b.linear(x, 8, Activation::None).unwrap();
        let _ = b.linear(y, 8, Activation::None).unwrap();
        let g = b.finish();
        assert!(find_siblings(&g, &pred!(lin: OpKind::Linear { .. }), 2).is_empty());
    }

    #[test]
    fn chain_rejects_interior_node_with_multiple_consumers() {
        // conv -> relu -> tanh where the *interior* relu also feeds a
        // second consumer: the 3-node chain must not match (the chain body
        // could not be deleted wholesale), while the conv->relu prefix —
        // whose interior is empty — still does.
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let r = b.relu(c).unwrap();
        let _ = b.op(OpKind::Tanh, &[r]).unwrap();
        let _ = b.op(OpKind::Sigmoid, &[r]).unwrap(); // second consumer of relu
        let g = b.finish();
        let triple = find_chains(
            &g,
            &[
                pred!(conv: OpKind::Conv2d { .. }),
                pred!(relu: OpKind::Relu),
                pred!(tanh: OpKind::Tanh),
            ],
        );
        assert!(triple.is_empty(), "interior multi-consumer chain must not match");
        let pair = find_chains(
            &g,
            &[pred!(conv: OpKind::Conv2d { .. }), pred!(relu: OpKind::Relu)],
        );
        assert_eq!(pair.len(), 1, "the 2-chain has no interior node and stays valid");
    }

    #[test]
    fn siblings_order_is_deterministic_and_sorted() {
        // The environment exposes location indices to the agent (§3.1.3),
        // so sibling groups must come out in one stable order: sources in
        // (node, port) order, members sorted, combinations lexicographic.
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 16]);
        let y = b.input(&[1, 16]);
        let _ = b.linear(y, 8, Activation::None).unwrap();
        let _ = b.linear(y, 8, Activation::None).unwrap();
        let _ = b.linear(x, 8, Activation::None).unwrap();
        let _ = b.linear(x, 8, Activation::None).unwrap();
        let g = b.finish();
        let run = || find_siblings(&g, &pred!(lin: OpKind::Linear { .. }), 2);
        let groups = run();
        assert_eq!(groups, run(), "repeat calls must agree exactly");
        assert_eq!(groups.len(), 2);
        for grp in &groups {
            assert!(grp.windows(2).all(|w| w[0] < w[1]), "members sorted");
        }
        // Groups ordered by shared-source node id: x's pair before y's.
        let src_of = |grp: &Vec<NodeId>| g.node(grp[0]).inputs[0].node;
        assert!(src_of(&groups[0]) < src_of(&groups[1]));
    }

    #[test]
    fn combinations_count() {
        let items: Vec<NodeId> = (0..5).map(NodeId).collect();
        let mut out = Vec::new();
        combinations(&items, 3, &mut out);
        assert_eq!(out.len(), 10);
        // All unique and sorted.
        for c in &out {
            assert!(c.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn relevance_is_union_of_predicates() {
        let rel = OpRelevance::of(&[
            |op| matches!(op, OpKind::Relu),
            |op| matches!(op, OpKind::Tanh),
        ]);
        assert!(rel.matches(&OpKind::Relu));
        assert!(rel.matches(&OpKind::Tanh));
        assert!(!rel.matches(&OpKind::Sigmoid));
        assert!(!rel.matches(&OpKind::Add));
    }

    #[test]
    fn deterministic_order() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        for _ in 0..3 {
            let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
            let _ = b.relu(c).unwrap();
        }
        let g = b.finish();
        let p = || {
            find_chains(
                &g,
                &[pred!(conv: OpKind::Conv2d { .. }), pred!(relu: OpKind::Relu)],
            )
        };
        assert_eq!(p(), p());
        assert_eq!(p().len(), 3);
    }
}
