//! TASO-style automatic substitution generation (§3.2, Fig. 3).
//!
//! Pipeline, mirroring TASO §4:
//!  1. **Enumerate** all small graphs (up to `max_ops` ops) over an operator
//!     alphabet applied to a fixed set of symbolic input slots, with tensor
//!     sizes bounded to 4x4x4x4 ("we limit the input tensor size to a
//!     maximum of 4x4x4x4 during the verification process").
//!  2. **Fingerprint** each graph by evaluating it on shared random inputs
//!     with the reference interpreter and hashing the (rounded) outputs.
//!  3. **Group** graphs by fingerprint; every pair inside a group is a
//!     substitution candidate.
//!  4. **Verify** candidates exactly on fresh random draws.
//!  5. **Prune** trivial pairs (Fig. 3): identical canonical hashes catch
//!     input renamings (3a); common-subgraph pairs where one side extends
//!     the other by an identical suffix are skipped via hash containment.

use std::collections::HashMap;

use crate::graph::{canonical_hash, Activation, Graph, OpKind};
use crate::interp::semantically_equal;

#[derive(Debug, Clone)]
pub struct Candidate {
    pub lhs: Graph,
    pub rhs: Graph,
    pub verified: bool,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct GenStats {
    pub enumerated: usize,
    pub groups: usize,
    pub candidates: usize,
    pub pruned_renaming: usize,
    pub pruned_common: usize,
    pub verified: usize,
}

/// Operator alphabet for enumeration. Kept to ewise/activation/shape ops:
/// exactly the family where TASO's generator finds its algebraic identities.
fn alphabet() -> Vec<OpKind> {
    vec![
        OpKind::Add,
        OpKind::Mul,
        OpKind::Relu,
        OpKind::Tanh,
        OpKind::Identity,
        OpKind::Transpose { perm: vec![1, 0] },
        OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
        OpKind::MatMul { trans_a: false, trans_b: true, act: Activation::None },
        OpKind::Scale { factor: 0.5 },
    ]
}

/// Enumerate all graphs with exactly `n_inputs` 4x4 inputs and up to
/// `max_ops` ops, single output, deduplicated on canonical hash.
///
/// Thin wrapper over [`synth::enumerate_with`] with this module's legacy
/// alphabet — the full synthesis pipeline (configurable alphabets, tiering,
/// serialisation) lives in [`crate::xfer::synth`].
pub fn enumerate_graphs(n_inputs: usize, max_ops: usize) -> Vec<Graph> {
    crate::xfer::synth::enumerate_with(n_inputs, max_ops, &alphabet())
}

/// Evaluate a graph on shared random inputs and hash the outputs.
fn fingerprint(g: &Graph, seed: u64) -> Option<u64> {
    crate::xfer::synth::graph_fingerprint(g, seed)
}

/// Run the full generation pipeline.
pub fn generate(n_inputs: usize, max_ops: usize, seed: u64) -> (Vec<Candidate>, GenStats) {
    let mut stats = GenStats::default();
    let graphs = enumerate_graphs(n_inputs, max_ops);
    stats.enumerated = graphs.len();

    let mut groups: HashMap<u64, Vec<usize>> = HashMap::new();
    for (i, g) in graphs.iter().enumerate() {
        if let Some(fp) = fingerprint(g, seed) {
            groups.entry(fp).or_default().push(i);
        }
    }
    stats.groups = groups.values().filter(|v| v.len() > 1).count();

    let mut candidates = Vec::new();
    let mut keys: Vec<u64> = groups.keys().copied().collect();
    keys.sort_unstable();
    for key in keys {
        let members = &groups[&key];
        if members.len() < 2 {
            continue;
        }
        for i in 0..members.len() {
            for j in i + 1..members.len() {
                stats.candidates += 1;
                let (a, b) = (&graphs[members[i]], &graphs[members[j]]);
                // Prune Fig. 3a: pure input renaming => identical canonical hash.
                if canonical_hash(a) == canonical_hash(b) {
                    stats.pruned_renaming += 1;
                    continue;
                }
                // Prune Fig. 3b: common-subgraph pairs where both sides
                // have the same op multiset (differ only in which shared
                // node they re-use) and one is not cheaper.
                if op_multiset(a) == op_multiset(b) && a.n_ops() == b.n_ops() {
                    stats.pruned_common += 1;
                    continue;
                }
                let verified = semantically_equal(a, b, 3, seed ^ 0x5555, 1e-3).unwrap_or(false);
                if verified {
                    stats.verified += 1;
                }
                candidates.push(Candidate { lhs: a.clone(), rhs: b.clone(), verified });
            }
        }
    }
    (candidates, stats)
}

fn op_multiset(g: &Graph) -> Vec<u64> {
    let mut v: Vec<u64> = g
        .live_ids()
        .filter(|id| !matches!(g.node(*id).op, OpKind::Input | OpKind::Weight))
        .map(|id| g.node(id).op.attr_hash())
        .collect();
    v.sort_unstable();
    v
}

/// Verify every rule in the standard library against a set of anchor
/// graphs using the interpreter — the "verification" stage applied to the
/// curated rules instead of enumerated ones. Returns (rule, sites checked).
pub fn verify_library(
    lib: &crate::xfer::RuleSet,
    graphs: &[Graph],
    seed: u64,
) -> anyhow::Result<Vec<(String, usize)>> {
    let mut report = Vec::new();
    for rule in &lib.rules {
        let mut checked = 0;
        for g in graphs {
            for loc in rule.find(g).into_iter().take(2) {
                let mut g2 = g.clone();
                crate::xfer::apply_rule(&mut g2, rule.as_ref(), &loc)?;
                anyhow::ensure!(
                    semantically_equal(g, &g2, 2, seed, 2e-3)?,
                    "rule {} failed verification at {:?}",
                    rule.name(),
                    loc
                );
                checked += 1;
            }
        }
        report.push((rule.name().to_string(), checked));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumeration_is_bounded_and_deduped() {
        let graphs = enumerate_graphs(2, 1);
        assert!(!graphs.is_empty());
        let mut hashes: Vec<u64> = graphs.iter().map(canonical_hash).collect();
        let n = hashes.len();
        hashes.sort_unstable();
        hashes.dedup();
        assert_eq!(hashes.len(), n, "structural duplicates survived");
    }

    #[test]
    fn enumeration_count_keeps_distinct_wirings() {
        // Regression for the canonical-hash dedup key. Over the legacy
        // 9-op alphabet with 2 inputs and 1 op, the distinct graphs modulo
        // input renaming are exactly 13: each of the 4 binary ops
        // contributes {f(x, x)} and {f(x, y) ≅ f(y, x)}, each of the 5
        // unary ops contributes one. A dedup key blind to source wiring
        // (the old shape-only source hash) collapses f(x, x) into f(x, y)
        // and reports 9.
        let graphs = enumerate_graphs(2, 1);
        assert_eq!(graphs.len(), 13, "enumeration count drifted");
        let n_add = graphs
            .iter()
            .filter(|g| g.live_ids().any(|id| matches!(g.node(id).op, OpKind::Add)))
            .count();
        assert_eq!(n_add, 2, "add(x, y) and add(x, x) must both survive dedup");
    }

    #[test]
    fn generator_finds_known_identities() {
        // Depth-2 over {add, mul, relu, ...} must rediscover, e.g.,
        // relu(relu(x)) == relu(x).
        let (cands, stats) = generate(2, 2, 7);
        assert!(stats.enumerated > 10);
        assert!(stats.verified > 0, "no identities verified: {:?}", stats);
        assert!(cands.iter().any(|c| c.verified));
    }

    #[test]
    fn pruning_counts_recorded() {
        let (_, stats) = generate(2, 2, 13);
        // The common-subgraph prune must fire (commutativity pairs).
        assert!(stats.pruned_common + stats.pruned_renaming > 0, "{:?}", stats);
    }

    #[test]
    fn verified_candidates_actually_equal() {
        let (cands, _) = generate(2, 2, 21);
        for c in cands.iter().filter(|c| c.verified).take(10) {
            assert!(semantically_equal(&c.lhs, &c.rhs, 2, 99, 1e-3).unwrap());
        }
    }

    #[test]
    fn library_passes_interpreter_verification() {
        let lib = crate::xfer::library::standard_library();
        // Small anchor graphs: keep the interpreter fast.
        let mut graphs = Vec::new();
        {
            let mut b = crate::graph::GraphBuilder::new();
            let x = b.input(&[1, 3, 6, 6]);
            let c = b.conv_bn_relu(x, 4, 3, 1, crate::graph::PadMode::Same).unwrap();
            let c2 = b.conv(c, 4, 1, 1, crate::graph::PadMode::Same).unwrap();
            let c3 = b.conv(c2, 4, 1, 1, crate::graph::PadMode::Same).unwrap();
            let _ = b.maxpool(c3, 2, 2).unwrap();
            graphs.push(b.finish());
        }
        {
            let mut b = crate::graph::GraphBuilder::new();
            let x = b.input(&[1, 4, 8]);
            let _ = b.transformer_encoder(x, 2, 2).unwrap();
            graphs.push(b.finish());
        }
        let report = verify_library(&lib, &graphs, 3).unwrap();
        let total: usize = report.iter().map(|(_, n)| n).sum();
        assert!(total > 10, "too few sites verified: {total}");
    }
}
