//! PPO update driver: assembles fixed-size batches and runs the
//! `ctrl_train` artifact (clipped surrogate, entropy bonus — the loss lives
//! in L2, this module owns batching and statistics).

use xla::Literal;

use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, scalar_f32, Engine, ParamStore};
use crate::util::Rng;

use super::policy::PolicyDims;

#[derive(Debug, Clone, Copy)]
pub struct PpoCfg {
    pub gamma: f32,
    pub lam: f32,
    pub clip: f32,
    pub lr: f32,
    pub ent_coef: f32,
    /// Gradient steps per collected batch.
    pub epochs: usize,
}

impl Default for PpoCfg {
    fn default() -> Self {
        Self { gamma: 0.99, lam: 0.95, clip: 0.2, lr: 3e-4, ent_coef: 0.01, epochs: 3 }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct PpoStats {
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
}

/// Accumulates transitions; `build` resamples to the artifact's fixed B.
#[derive(Debug, Default, Clone)]
pub struct PpoBuffer {
    pub z: Vec<Vec<f32>>,
    pub h: Vec<Vec<f32>>,
    pub act: Vec<(usize, usize)>,
    pub logp: Vec<f32>,
    pub adv: Vec<f32>,
    pub ret: Vec<f32>,
    pub xmask: Vec<Vec<f32>>,
    pub lmask: Vec<Vec<f32>>,
}

impl PpoBuffer {
    pub fn len(&self) -> usize {
        self.act.len()
    }

    pub fn is_empty(&self) -> bool {
        self.act.is_empty()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        z: Vec<f32>,
        h: Vec<f32>,
        act: (usize, usize),
        logp: f32,
        adv: f32,
        ret: f32,
        xmask: Vec<f32>,
        lmask: Vec<f32>,
    ) {
        self.z.push(z);
        self.h.push(h);
        self.act.push(act);
        self.logp.push(logp);
        self.adv.push(adv);
        self.ret.push(ret);
        self.xmask.push(xmask);
        self.lmask.push(lmask);
    }

    pub fn clear(&mut self) {
        *self = Self::default();
    }

    /// Materialise the fixed-size artifact batch (sampling with replacement
    /// when fewer than `b_ppo` transitions are available).
    pub fn build_args(
        &self,
        dims: &PolicyDims,
        b_ppo: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<Literal>> {
        anyhow::ensure!(!self.is_empty(), "empty PPO buffer");
        let idx: Vec<usize> = if self.len() >= b_ppo {
            let mut all: Vec<usize> = (0..self.len()).collect();
            rng.shuffle(&mut all);
            all.truncate(b_ppo);
            all
        } else {
            (0..b_ppo).map(|_| rng.below(self.len())).collect()
        };
        let mut z = Vec::with_capacity(b_ppo * dims.zdim);
        let mut h = Vec::with_capacity(b_ppo * dims.rdim);
        let mut act = Vec::with_capacity(b_ppo * 2);
        let mut logp = Vec::with_capacity(b_ppo);
        let mut adv = Vec::with_capacity(b_ppo);
        let mut ret = Vec::with_capacity(b_ppo);
        let mut xm = Vec::with_capacity(b_ppo * dims.x1);
        let mut lm = Vec::with_capacity(b_ppo * dims.max_locs);
        for &i in &idx {
            z.extend_from_slice(&self.z[i]);
            h.extend_from_slice(&self.h[i]);
            act.push(self.act[i].0 as i32);
            act.push(self.act[i].1 as i32);
            logp.push(self.logp[i]);
            adv.push(self.adv[i]);
            ret.push(self.ret[i]);
            xm.extend_from_slice(&self.xmask[i]);
            lm.extend_from_slice(&self.lmask[i]);
        }
        Ok(vec![
            lit_f32(&z, &[b_ppo, dims.zdim])?,
            lit_f32(&h, &[b_ppo, dims.rdim])?,
            lit_i32(&act, &[b_ppo, 2])?,
            lit_f32(&logp, &[b_ppo])?,
            lit_f32(&adv, &[b_ppo])?,
            lit_f32(&ret, &[b_ppo])?,
            lit_f32(&xm, &[b_ppo, dims.x1])?,
            lit_f32(&lm, &[b_ppo, dims.max_locs])?,
        ])
    }
}

/// One PPO update: `cfg.epochs` gradient steps on resampled batches.
pub fn ppo_update(
    engine: &Engine,
    ctrl: &mut ParamStore,
    buffer: &PpoBuffer,
    dims: &PolicyDims,
    cfg: &PpoCfg,
    rng: &mut Rng,
) -> anyhow::Result<PpoStats> {
    let b_ppo = engine.manifest.hp_usize("B_PPO")?;
    let mut stats = PpoStats::default();
    for _ in 0..cfg.epochs {
        let mut args = ctrl.train_args()?;
        args.extend(buffer.build_args(dims, b_ppo, rng)?);
        args.push(lit_scalar_f32(cfg.lr));
        args.push(lit_scalar_f32(cfg.clip));
        args.push(lit_scalar_f32(cfg.ent_coef));
        let out = engine.exec("ctrl_train", &args)?;
        ctrl.absorb(&out)?;
        stats = PpoStats {
            pi_loss: scalar_f32(&out[4])?,
            v_loss: scalar_f32(&out[5])?,
            entropy: scalar_f32(&out[6])?,
            approx_kl: scalar_f32(&out[7])?,
        };
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> PolicyDims {
        PolicyDims { zdim: 4, rdim: 8, x1: 5, max_locs: 10 }
    }

    fn push_n(buf: &mut PpoBuffer, n: usize) {
        for i in 0..n {
            buf.push(
                vec![i as f32; 4],
                vec![0.0; 8],
                (i % 5, i % 10),
                -1.0,
                0.5,
                1.0,
                vec![1.0; 5],
                vec![1.0; 10],
            );
        }
    }

    #[test]
    fn build_args_pads_small_buffers() {
        let mut buf = PpoBuffer::default();
        push_n(&mut buf, 3);
        let mut rng = Rng::new(0);
        let args = buf.build_args(&dims(), 16, &mut rng).unwrap();
        assert_eq!(args.len(), 8);
        assert_eq!(args[0].element_count(), 16 * 4);
        assert_eq!(args[2].element_count(), 16 * 2);
    }

    #[test]
    fn build_args_subsamples_large_buffers() {
        let mut buf = PpoBuffer::default();
        push_n(&mut buf, 100);
        let mut rng = Rng::new(1);
        let args = buf.build_args(&dims(), 16, &mut rng).unwrap();
        assert_eq!(args[4].element_count(), 16);
    }

    #[test]
    fn empty_buffer_errors() {
        let buf = PpoBuffer::default();
        let mut rng = Rng::new(2);
        assert!(buf.build_args(&dims(), 16, &mut rng).is_err());
    }
}
