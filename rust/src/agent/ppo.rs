//! PPO update driver: assembles fixed-size batches and runs the
//! `ctrl_train` program (clipped surrogate, entropy bonus — the loss lives
//! in the backend, this module owns batching and statistics).

use crate::runtime::{Backend, ParamStore, TensorView};
use crate::util::Rng;

use super::action::Action;
use super::policy::PolicyDims;

#[derive(Debug, Clone, Copy)]
pub struct PpoCfg {
    pub gamma: f32,
    pub lam: f32,
    pub clip: f32,
    pub lr: f32,
    pub ent_coef: f32,
    /// Gradient steps per collected batch.
    pub epochs: usize,
}

impl Default for PpoCfg {
    fn default() -> Self {
        Self { gamma: 0.99, lam: 0.95, clip: 0.2, lr: 3e-4, ent_coef: 0.01, epochs: 3 }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct PpoStats {
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
}

/// Accumulates transitions; `batch` resamples to the program's fixed B.
#[derive(Debug, Default, Clone)]
pub struct PpoBuffer {
    pub z: Vec<Vec<f32>>,
    pub h: Vec<Vec<f32>>,
    pub act: Vec<Action>,
    pub logp: Vec<f32>,
    pub adv: Vec<f32>,
    pub ret: Vec<f32>,
    pub xmask: Vec<Vec<f32>>,
    pub lmask: Vec<Vec<f32>>,
    /// Policy version the buffered transitions were acted under, set by
    /// the first [`PpoBuffer::note_version`] (`None` until then). One
    /// buffer = one PPO batch = one version; see `note_version`.
    version: Option<u64>,
}

/// An owned, fixed-size `ctrl_train` batch; [`PpoBatch::views`] borrows it
/// as the eight tensor arguments following `(theta, m, v, t)`.
pub struct PpoBatch {
    pub b: usize,
    dims: PolicyDims,
    z: Vec<f32>,
    h: Vec<f32>,
    act: Vec<i32>,
    logp: Vec<f32>,
    adv: Vec<f32>,
    ret: Vec<f32>,
    xm: Vec<f32>,
    lm: Vec<f32>,
}

impl PpoBatch {
    pub fn views(&self) -> Vec<TensorView<'_>> {
        let (b, d) = (self.b, &self.dims);
        vec![
            TensorView::f32(&self.z, &[b, d.zdim]),
            TensorView::f32(&self.h, &[b, d.rdim]),
            TensorView::i32(&self.act, &[b, 2]),
            TensorView::f32(&self.logp, &[b]),
            TensorView::f32(&self.adv, &[b]),
            TensorView::f32(&self.ret, &[b]),
            TensorView::f32(&self.xm, &[b, d.x1]),
            TensorView::f32(&self.lm, &[b, d.max_locs]),
        ]
    }
}

impl PpoBuffer {
    pub fn len(&self) -> usize {
        self.act.len()
    }

    pub fn is_empty(&self) -> bool {
        self.act.is_empty()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        z: Vec<f32>,
        h: Vec<f32>,
        act: Action,
        logp: f32,
        adv: f32,
        ret: f32,
        xmask: Vec<f32>,
        lmask: Vec<f32>,
    ) {
        self.z.push(z);
        self.h.push(h);
        self.act.push(act);
        self.logp.push(logp);
        self.adv.push(adv);
        self.ret.push(ret);
        self.xmask.push(xmask);
        self.lmask.push(lmask);
    }

    pub fn clear(&mut self) {
        *self = Self::default();
    }

    /// Declare the policy version (`ParamStore::version`) the transitions
    /// being pushed were acted under. The first call pins the buffer's
    /// version; a later call with a *different* version is a typed error
    /// — a PPO batch must never mix trajectories collected under two
    /// policy versions (the importance ratios would silently be computed
    /// against the wrong behaviour policy). [`PpoBuffer::clear`] resets
    /// the pin along with the data.
    pub fn note_version(&mut self, version: u64) -> anyhow::Result<()> {
        match self.version {
            None => {
                self.version = Some(version);
                Ok(())
            }
            Some(v) if v == version => Ok(()),
            Some(v) => anyhow::bail!(
                "refusing to mix trajectories from policy versions {v} and {version} \
                 in one PPO batch"
            ),
        }
    }

    /// The pinned policy version, if [`PpoBuffer::note_version`] ran.
    pub fn policy_version(&self) -> Option<u64> {
        self.version
    }

    /// Materialise the fixed-size train batch (sampling with replacement
    /// when fewer than `b_ppo` transitions are available).
    pub fn batch(
        &self,
        dims: &PolicyDims,
        b_ppo: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<PpoBatch> {
        anyhow::ensure!(!self.is_empty(), "empty PPO buffer");
        let idx: Vec<usize> = if self.len() >= b_ppo {
            let mut all: Vec<usize> = (0..self.len()).collect();
            rng.shuffle(&mut all);
            all.truncate(b_ppo);
            all
        } else {
            (0..b_ppo).map(|_| rng.below(self.len())).collect()
        };
        let mut batch = PpoBatch {
            b: b_ppo,
            dims: *dims,
            z: Vec::with_capacity(b_ppo * dims.zdim),
            h: Vec::with_capacity(b_ppo * dims.rdim),
            act: Vec::with_capacity(b_ppo * 2),
            logp: Vec::with_capacity(b_ppo),
            adv: Vec::with_capacity(b_ppo),
            ret: Vec::with_capacity(b_ppo),
            xm: Vec::with_capacity(b_ppo * dims.x1),
            lm: Vec::with_capacity(b_ppo * dims.max_locs),
        };
        for &i in &idx {
            batch.z.extend_from_slice(&self.z[i]);
            batch.h.extend_from_slice(&self.h[i]);
            batch.act.push(self.act[i].slot as i32);
            batch.act.push(self.act[i].loc as i32);
            batch.logp.push(self.logp[i]);
            batch.adv.push(self.adv[i]);
            batch.ret.push(self.ret[i]);
            batch.xm.extend_from_slice(&self.xmask[i]);
            batch.lm.extend_from_slice(&self.lmask[i]);
        }
        Ok(batch)
    }
}

/// One PPO update: `cfg.epochs` gradient steps on resampled batches,
/// driven through [`Backend::train_step`] (the host backend updates the
/// store's Adam state in place — no parameter-vector copies per epoch).
pub fn ppo_update(
    backend: &dyn Backend,
    ctrl: &mut ParamStore,
    buffer: &PpoBuffer,
    dims: &PolicyDims,
    cfg: &PpoCfg,
    rng: &mut Rng,
) -> anyhow::Result<PpoStats> {
    let b_ppo = backend.hp("B_PPO")?;
    let mut stats = PpoStats::default();
    for _ in 0..cfg.epochs {
        let batch = buffer.batch(dims, b_ppo, rng)?;
        let mut rest = batch.views();
        rest.push(TensorView::ScalarF32(cfg.lr));
        rest.push(TensorView::ScalarF32(cfg.clip));
        rest.push(TensorView::ScalarF32(cfg.ent_coef));
        let out = backend.train_step("ctrl_train", ctrl, &rest)?;
        drop(rest);
        stats = PpoStats {
            pi_loss: out[0].data[0],
            v_loss: out[1].data[0],
            entropy: out[2].data[0],
            approx_kl: out[3].data[0],
        };
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> PolicyDims {
        PolicyDims { zdim: 4, rdim: 8, x1: 5, max_locs: 10 }
    }

    fn push_n(buf: &mut PpoBuffer, n: usize) {
        for i in 0..n {
            buf.push(
                vec![i as f32; 4],
                vec![0.0; 8],
                Action::new(i % 5, i % 10),
                -1.0,
                0.5,
                1.0,
                vec![1.0; 5],
                vec![1.0; 10],
            );
        }
    }

    #[test]
    fn batch_pads_small_buffers() {
        let mut buf = PpoBuffer::default();
        push_n(&mut buf, 3);
        let mut rng = Rng::new(0);
        let batch = buf.batch(&dims(), 16, &mut rng).unwrap();
        let views = batch.views();
        assert_eq!(views.len(), 8);
        assert_eq!(views[0].n_elems(), 16 * 4);
        assert_eq!(views[2].n_elems(), 16 * 2);
    }

    #[test]
    fn batch_subsamples_large_buffers() {
        let mut buf = PpoBuffer::default();
        push_n(&mut buf, 100);
        let mut rng = Rng::new(1);
        let batch = buf.batch(&dims(), 16, &mut rng).unwrap();
        assert_eq!(batch.views()[4].n_elems(), 16);
    }

    #[test]
    fn empty_buffer_errors() {
        let buf = PpoBuffer::default();
        let mut rng = Rng::new(2);
        assert!(buf.batch(&dims(), 16, &mut rng).is_err());
    }

    #[test]
    fn note_version_pins_one_policy_version() {
        let mut buf = PpoBuffer::default();
        assert_eq!(buf.policy_version(), None);
        buf.note_version(5).unwrap();
        push_n(&mut buf, 2);
        buf.note_version(5).unwrap(); // same version: fine
        assert_eq!(buf.policy_version(), Some(5));
        // Boundary: the first transition collected under the *next*
        // params must be rejected from this batch.
        let err = buf.note_version(6).unwrap_err();
        assert!(err.to_string().contains("refusing to mix"), "got: {err}");
    }

    #[test]
    fn clear_resets_the_version_pin() {
        let mut buf = PpoBuffer::default();
        buf.note_version(5).unwrap();
        push_n(&mut buf, 2);
        buf.clear();
        assert!(buf.is_empty());
        assert_eq!(buf.policy_version(), None);
        buf.note_version(6).unwrap(); // a fresh buffer may start the next version
        assert_eq!(buf.policy_version(), Some(6));
    }
}
