//! The single source of truth for the action space: slot-space actions and
//! the NO-OP mapping between the learned models' fixed slot space and the
//! environment's rule indices.
//!
//! The models act in *slot space*: `N_XFERS1` transformation slots with the
//! NO-OP pinned to the **last** slot (the AOT artifacts reserve the slot
//! count at export time; the rule library may be smaller). The environment
//! uses *rule space*: rule indices `0..rules.len()` with NO-OP at
//! `rules.len()`. Before this type, that mapping lived in three places
//! (`PolicyDims::noop`, `DreamEnv::noop`, `Pipeline::to_env_action`);
//! [`ActionSpace`] now owns both directions.

/// A `(transformation slot, location)` action in the models' slot space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Action {
    pub slot: usize,
    pub loc: usize,
}

impl Action {
    pub fn new(slot: usize, loc: usize) -> Self {
        Self { slot, loc }
    }

    /// The raw `(slot, loc)` pair (world-model embeddings, episode storage).
    pub fn pair(self) -> (usize, usize) {
        (self.slot, self.loc)
    }
}

/// Slot-space geometry plus the environment-side NO-OP index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ActionSpace {
    n_slots: usize,
    env_noop: usize,
}

impl ActionSpace {
    /// `n_slots` = N_XFERS1 (incl. NO-OP); `env_noop` = the environment's
    /// NO-OP action id (`rules.len()`).
    pub fn new(n_slots: usize, env_noop: usize) -> Self {
        assert!(n_slots >= 1, "action space needs at least the NO-OP slot");
        assert!(
            env_noop < n_slots,
            "env rule count {env_noop} does not fit {n_slots} slots (incl. NO-OP)"
        );
        Self { n_slots, env_noop }
    }

    /// Slot-space-only view for contexts with no real environment (dream
    /// rollouts): every non-NO-OP slot maps to itself.
    pub fn slots_only(n_slots: usize) -> Self {
        Self::new(n_slots, n_slots - 1)
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// The NO-OP slot: always the last one.
    pub fn noop_slot(&self) -> usize {
        self.n_slots - 1
    }

    pub fn noop(&self) -> Action {
        Action::new(self.noop_slot(), 0)
    }

    pub fn is_noop(&self, a: Action) -> bool {
        a.slot == self.noop_slot()
    }

    /// Slot action -> environment `(xfer, loc)` action (NO-OP remaps to the
    /// environment's `rules.len()` id).
    pub fn to_env(&self, a: Action) -> (usize, usize) {
        if self.is_noop(a) {
            (self.env_noop, 0)
        } else {
            (a.slot, a.loc)
        }
    }

    /// Environment action -> slot action (inverse of [`ActionSpace::to_env`]).
    pub fn from_env(&self, (xfer, loc): (usize, usize)) -> Action {
        if xfer == self.env_noop {
            self.noop()
        } else {
            Action::new(xfer, loc)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_is_last_slot() {
        let s = ActionSpace::new(49, 40);
        assert_eq!(s.noop_slot(), 48);
        assert_eq!(s.noop(), Action::new(48, 0));
        assert!(s.is_noop(Action::new(48, 7)));
        assert!(!s.is_noop(Action::new(0, 0)));
    }

    #[test]
    fn env_round_trip() {
        let s = ActionSpace::new(49, 40);
        // Ordinary actions pass through unchanged.
        assert_eq!(s.to_env(Action::new(3, 17)), (3, 17));
        assert_eq!(s.from_env((3, 17)), Action::new(3, 17));
        // NO-OP remaps slot 48 <-> env id 40.
        assert_eq!(s.to_env(s.noop()), (40, 0));
        assert_eq!(s.from_env((40, 5)), s.noop());
    }

    #[test]
    fn slots_only_maps_noop_to_itself() {
        let s = ActionSpace::slots_only(5);
        assert_eq!(s.to_env(s.noop()), (4, 0));
        assert_eq!(s.to_env(Action::new(2, 9)), (2, 9));
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn env_noop_must_fit_slot_space() {
        let _ = ActionSpace::new(5, 5);
    }
}
