//! Random agent (§3.3.2): uniform over valid actions, used to collect the
//! world model's training distribution. Paper: "To train the world model,
//! we use a random agent. The probability of the agent choosing any action
//! from the set of valid actions is equal."

use crate::agent::buffer::{CompactState, Episode};
use crate::env::{Env, EnvPool, StateEncoder};
use crate::util::Rng;

/// Collect `n_episodes` random-policy episodes from `env`.
///
/// `noop_prob` injects occasional early termination so the world model sees
/// `done` transitions at varied depths (without it, every episode runs to
/// the step cap and the done head never trains).
/// `n_slots`: the artifact action-space width (N_XFERS + 1). Stored masks
/// and actions live in *slot space* (NO-OP = last slot) so they feed the
/// world-model embeddings directly.
pub fn collect_random_episodes(
    env: &mut Env,
    encoder: &StateEncoder,
    n_slots: usize,
    n_episodes: usize,
    noop_prob: f32,
    rng: &mut Rng,
) -> Vec<Episode> {
    (0..n_episodes)
        .map(|_| collect_one(env, encoder, n_slots, noop_prob, rng))
        .collect()
}

/// Collect `n_episodes` random episodes from a [`EnvPool`], B environments
/// at a time. The episode *counts* split round-robin (env `i` runs
/// `n/B + (i < n%B)` episodes), each env collecting its block back-to-back
/// from its own forked RNG, and the blocks are returned env-major (all of
/// env 0's episodes, then env 1's, ...). Ownership is deterministic and
/// bit-identical for any pool thread count.
pub fn collect_random_pool(
    pool: &mut EnvPool,
    encoder: &StateEncoder,
    n_slots: usize,
    n_episodes: usize,
    noop_prob: f32,
) -> Vec<Episode> {
    let b = pool.n_envs();
    let counts: Vec<usize> =
        (0..b).map(|i| n_episodes / b + usize::from(i < n_episodes % b)).collect();
    let per_env: Vec<Vec<Episode>> = pool.map_envs(|i, env, rng| {
        collect_random_episodes(env, encoder, n_slots, counts[i], noop_prob, rng)
    });
    per_env.into_iter().flatten().collect()
}

pub fn collect_one(
    env: &mut Env,
    encoder: &StateEncoder,
    n_slots: usize,
    noop_prob: f32,
    rng: &mut Rng,
) -> Episode {
    assert!(n_slots > env.rules.len(), "slot space smaller than rule set");
    let space = crate::agent::ActionSpace::new(n_slots, env.noop_action());
    env.reset();
    let mut ep = Episode::default();
    loop {
        let obs = env.observe();
        ep.states
            .push(CompactState::from_encoded(&encoder.encode(env.graph())));
        ep.xmasks.push(env.padded_xfer_mask(n_slots));

        let valid: Vec<usize> = (0..env.rules.len())
            .filter(|&i| obs.xfer_mask[i])
            .collect();
        let slot_action = if valid.is_empty() || rng.f32() < noop_prob {
            space.noop()
        } else {
            let x = valid[rng.below(valid.len())];
            let l = rng.below(obs.location_counts[x].max(1));
            crate::agent::Action::new(x, l)
        };
        let res = env.step(space.to_env(slot_action));
        ep.actions.push((slot_action.slot as u16, slot_action.loc as u16));
        ep.rewards.push(res.reward);
        ep.dones.push(if res.done { 1.0 } else { 0.0 });
        if res.done {
            // Final state snapshot (z_next target for the last step).
            ep.states
                .push(CompactState::from_encoded(&encoder.encode(env.graph())));
            ep.xmasks.push(env.padded_xfer_mask(n_slots));
            return ep;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, DeviceProfile};
    use crate::env::EnvConfig;
    use crate::graph::{GraphBuilder, PadMode};
    use crate::xfer::library::standard_library;

    #[test]
    fn episodes_have_consistent_lengths() {
        let rules = standard_library();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv_bn_relu(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.maxpool(c, 2, 2).unwrap();
        let mut env = Env::new(
            b.finish(),
            &rules,
            &cost,
            EnvConfig { max_steps: 6, ..Default::default() },
        );
        let encoder = StateEncoder::new(320, 32);
        let mut rng = Rng::new(3);
        let eps = collect_random_episodes(&mut env, &encoder, 49, 4, 0.1, &mut rng);
        assert_eq!(eps.len(), 4);
        for ep in &eps {
            assert!(!ep.is_empty());
            assert_eq!(ep.states.len(), ep.len() + 1);
            assert_eq!(ep.xmasks.len(), ep.len() + 1);
            assert_eq!(*ep.dones.last().unwrap(), 1.0);
            assert!(ep.len() <= 6);
        }
    }

    #[test]
    fn pool_collection_splits_episodes_round_robin() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv_bn_relu(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.maxpool(c, 2, 2).unwrap();
        let g = b.finish();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mk = |threads| {
            crate::env::EnvPool::new(
                &g,
                standard_library(),
                &cost,
                &crate::env::EnvPoolConfig {
                    n_envs: 3,
                    threads,
                    seed: 21,
                    env: EnvConfig { max_steps: 5, ..Default::default() },
                    ..Default::default()
                },
            )
        };
        let encoder = StateEncoder::new(320, 32);
        let eps = collect_random_pool(&mut mk(2), &encoder, 49, 7, 0.1);
        assert_eq!(eps.len(), 7);
        assert!(eps.iter().all(|e| !e.is_empty()));
        // Thread-count invariance of the collected set.
        let eps1 = collect_random_pool(&mut mk(1), &encoder, 49, 7, 0.1);
        assert_eq!(eps.len(), eps1.len());
        for (a, b) in eps.iter().zip(&eps1) {
            assert_eq!(a.actions, b.actions);
            assert_eq!(a.rewards, b.rewards);
        }
    }

    #[test]
    fn noop_prob_one_terminates_immediately() {
        let rules = standard_library();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let _ = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let mut env = Env::new(b.finish(), &rules, &cost, EnvConfig::default());
        let encoder = StateEncoder::new(320, 32);
        let mut rng = Rng::new(4);
        let ep = collect_one(&mut env, &encoder, 49, 1.0, &mut rng);
        assert_eq!(ep.len(), 1);
    }
}
