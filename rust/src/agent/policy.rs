//! Controller acting: masked two-step (xfer, location) sampling on top of
//! the `ctrl_policy_*` artifacts (§3.1.3: "using the same trunk network, we
//! first predict the transformation, apply the location mask for the
//! selected transformation, then predict the location").

use xla::Literal;

use crate::runtime::{lit_f32, to_vec_f32, Engine, ParamStore};
use crate::util::Rng;

/// Numerically stable masked log-softmax (masked entries -> -inf).
pub fn masked_log_softmax(logits: &[f32], mask: &[bool]) -> Vec<f32> {
    debug_assert_eq!(logits.len(), mask.len());
    let mx = logits
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m)
        .map(|(&l, _)| l)
        .fold(f32::NEG_INFINITY, f32::max);
    if !mx.is_finite() {
        return vec![f32::NEG_INFINITY; logits.len()];
    }
    let lse = logits
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m)
        .map(|(&l, _)| (l - mx).exp())
        .sum::<f32>()
        .ln()
        + mx;
    logits
        .iter()
        .zip(mask)
        .map(|(&l, &m)| if m { l - lse } else { f32::NEG_INFINITY })
        .collect()
}

fn argmax_masked(logits: &[f32], mask: &[bool]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, (&l, &m)) in logits.iter().zip(mask).enumerate() {
        if m && l > best_v {
            best_v = l;
            best = i;
        }
    }
    best
}

#[derive(Debug, Clone)]
pub struct ActOut {
    pub action: (usize, usize),
    pub logp: f32,
    pub value: f32,
}

/// Dimension bundle read once from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct PolicyDims {
    pub zdim: usize,
    pub rdim: usize,
    pub x1: usize,
    pub max_locs: usize,
}

impl PolicyDims {
    pub fn from_manifest(m: &crate::runtime::Manifest) -> anyhow::Result<Self> {
        Ok(Self {
            zdim: m.hp_usize("LATENT")?,
            rdim: m.hp_usize("RNN_HIDDEN")?,
            x1: m.hp_usize("N_XFERS1")?,
            max_locs: m.hp_usize("MAX_LOCS")?,
        })
    }

    pub fn noop(&self) -> usize {
        self.x1 - 1
    }
}

/// Run the batched policy artifact and sample per-row actions.
///
/// `xmask`: `b * x1` validity (>=0.5 is valid). `loc_mask(row, xfer)` gives
/// the location mask for that row's chosen xfer.
#[allow(clippy::too_many_arguments)]
pub fn act_batch(
    engine: &Engine,
    artifact: &str,
    dims: &PolicyDims,
    ctrl: &ParamStore,
    z: &[f32],
    h: &[f32],
    xmask: &[f32],
    loc_mask: impl Fn(usize, usize) -> Vec<bool>,
    rng: &mut Rng,
    greedy: bool,
) -> anyhow::Result<Vec<ActOut>> {
    let b = z.len() / dims.zdim;
    anyhow::ensure!(h.len() == b * dims.rdim && xmask.len() == b * dims.x1, "act_batch: bad arg sizes");
    let theta = engine.device_theta(ctrl)?;
    let rest: Vec<Literal> = vec![
        lit_f32(z, &[b, dims.zdim])?,
        lit_f32(h, &[b, dims.rdim])?,
    ];
    let out = engine.exec_with_theta(artifact, &theta, &rest)?;
    let xlogits = to_vec_f32(&out[0])?;
    let llogits = to_vec_f32(&out[1])?;
    let values = to_vec_f32(&out[2])?;

    let mut results = Vec::with_capacity(b);
    for row in 0..b {
        let xl = &xlogits[row * dims.x1..(row + 1) * dims.x1];
        let xm: Vec<bool> = xmask[row * dims.x1..(row + 1) * dims.x1]
            .iter()
            .map(|&m| m >= 0.5)
            .collect();
        let x_lsm = masked_log_softmax(xl, &xm);
        let x = if greedy { argmax_masked(xl, &xm) } else { rng.sample_logits_masked(xl, &xm) };
        let mut logp = x_lsm[x];

        let action = if x == dims.noop() {
            (x, 0)
        } else {
            let lm = loc_mask(row, x);
            let base = (row * dims.x1 + x) * dims.max_locs;
            let ll = &llogits[base..base + dims.max_locs];
            let l_lsm = masked_log_softmax(ll, &lm);
            let l = if greedy { argmax_masked(ll, &lm) } else { rng.sample_logits_masked(ll, &lm) };
            logp += l_lsm[l];
            (x, l)
        };
        results.push(ActOut { action, logp, value: values[row] });
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_log_softmax_normalises() {
        let lsm = masked_log_softmax(&[1.0, 2.0, 3.0], &[true, false, true]);
        assert_eq!(lsm[1], f32::NEG_INFINITY);
        let p: f32 = lsm.iter().filter(|v| v.is_finite()).map(|v| v.exp()).sum();
        assert!((p - 1.0).abs() < 1e-5);
    }

    #[test]
    fn all_masked_is_neg_inf() {
        let lsm = masked_log_softmax(&[1.0, 2.0], &[false, false]);
        assert!(lsm.iter().all(|v| *v == f32::NEG_INFINITY));
    }

    #[test]
    fn argmax_respects_mask() {
        assert_eq!(argmax_masked(&[5.0, 9.0, 1.0], &[true, false, true]), 0);
    }
}
