//! Controller acting: masked two-step (xfer, location) sampling on top of
//! the `ctrl_policy_*` programs (§3.1.3: "using the same trunk network, we
//! first predict the transformation, apply the location mask for the
//! selected transformation, then predict the location").
//!
//! [`PolicyNet`] is the typed acting API over any [`Backend`]: it owns the
//! program choice (`ctrl_policy_1` for single states, `ctrl_policy_b` for
//! dream batches), the masked sampling, and the guarantee that the NO-OP
//! slot is always selectable — a row whose predicted xfer mask is entirely
//! invalid would otherwise sample an arbitrary action with `logp = -inf`
//! and poison the PPO buffer.

use crate::runtime::{Backend, Manifest, ParamStore, TensorView};
use crate::util::Rng;

use super::action::{Action, ActionSpace};

/// Numerically stable masked log-softmax (masked entries -> -inf).
pub fn masked_log_softmax(logits: &[f32], mask: &[bool]) -> Vec<f32> {
    debug_assert_eq!(logits.len(), mask.len());
    let mx = logits
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m)
        .map(|(&l, _)| l)
        .fold(f32::NEG_INFINITY, f32::max);
    if !mx.is_finite() {
        return vec![f32::NEG_INFINITY; logits.len()];
    }
    let lse = logits
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m)
        .map(|(&l, _)| (l - mx).exp())
        .sum::<f32>()
        .ln()
        + mx;
    logits
        .iter()
        .zip(mask)
        .map(|(&l, &m)| if m { l - lse } else { f32::NEG_INFINITY })
        .collect()
}

fn argmax_masked(logits: &[f32], mask: &[bool]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, (&l, &m)) in logits.iter().zip(mask).enumerate() {
        if m && l > best_v {
            best_v = l;
            best = i;
        }
    }
    best
}

#[derive(Debug, Clone)]
pub struct ActOut {
    pub action: Action,
    pub logp: f32,
    pub value: f32,
}

/// Dimension bundle read once from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct PolicyDims {
    pub zdim: usize,
    pub rdim: usize,
    pub x1: usize,
    pub max_locs: usize,
}

impl PolicyDims {
    pub fn from_manifest(m: &Manifest) -> anyhow::Result<Self> {
        Ok(Self {
            zdim: m.hp_usize("LATENT")?,
            rdim: m.hp_usize("RNN_HIDDEN")?,
            x1: m.hp_usize("N_XFERS1")?,
            max_locs: m.hp_usize("MAX_LOCS")?,
        })
    }
}

/// One acting batch: latents, recurrent context and per-row xfer validity,
/// all row-major (`b * zdim`, `b * rdim`, `b * x1`).
#[derive(Debug, Clone, Copy)]
pub struct ObsBatch<'a> {
    pub z: &'a [f32],
    pub h: &'a [f32],
    pub xmask: &'a [f32],
}

/// Typed acting API over the controller programs of any backend.
pub struct PolicyNet<'b> {
    pub backend: &'b dyn Backend,
    pub dims: PolicyDims,
    /// Slot-space geometry (NO-OP handling during sampling).
    pub space: ActionSpace,
    /// Batch width of the `ctrl_policy_b` program (B_DREAM).
    pub batch_b: usize,
}

impl<'b> PolicyNet<'b> {
    pub fn new(backend: &'b dyn Backend) -> anyhow::Result<Self> {
        let dims = PolicyDims::from_manifest(backend.manifest())?;
        Ok(Self {
            backend,
            dims,
            space: ActionSpace::slots_only(dims.x1),
            batch_b: backend.hp("B_DREAM")?,
        })
    }

    /// Run the `ctrl_policy_*` forward for `b` rows and return the flat
    /// `(xlogits, llogits, values)` buffers.
    ///
    /// Any width is accepted: `b == 1` and `b == B_DREAM` map directly to
    /// the exported programs; every other width (an EnvPool of alive
    /// evaluation rows, an odd last collection batch) is chunked into
    /// `B_DREAM`-wide program calls — the final chunk padded by repeating
    /// its first row — and dispatched as one
    /// [`exec_with_params_batch`](crate::runtime::Backend::exec_with_params_batch),
    /// so parameter binding and manifest lookup are amortised across the
    /// whole observation batch. Rows are computed independently by every
    /// backend program, so padded rows cannot perturb real ones and the
    /// per-row outputs are bit-identical to `b` separate
    /// `ctrl_policy_1` calls.
    pub fn forward_rows(
        &self,
        ctrl: &ParamStore,
        z: &[f32],
        h: &[f32],
        b: usize,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let dims = &self.dims;
        if b == 1 || b == self.batch_b {
            let program = if b == 1 { "ctrl_policy_1" } else { "ctrl_policy_b" };
            let out = self.backend.exec_with_params(
                program,
                ctrl,
                &[TensorView::f32(z, &[b, dims.zdim]), TensorView::f32(h, &[b, dims.rdim])],
            )?;
            let mut it = out.into_iter().map(|t| t.data);
            return Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()));
        }
        // Chunk + pad to the exported B_DREAM width.
        let bb = self.batch_b;
        let n_chunks = b.div_ceil(bb);
        let mut bufs: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_chunks);
        for chunk in 0..n_chunks {
            let lo = chunk * bb;
            let hi = (lo + bb).min(b);
            let mut zc = Vec::with_capacity(bb * dims.zdim);
            let mut hc = Vec::with_capacity(bb * dims.rdim);
            for row in lo..hi {
                zc.extend_from_slice(&z[row * dims.zdim..(row + 1) * dims.zdim]);
                hc.extend_from_slice(&h[row * dims.rdim..(row + 1) * dims.rdim]);
            }
            for _ in hi..lo + bb {
                zc.extend_from_slice(&z[lo * dims.zdim..(lo + 1) * dims.zdim]);
                hc.extend_from_slice(&h[lo * dims.rdim..(lo + 1) * dims.rdim]);
            }
            bufs.push((zc, hc));
        }
        let rests: Vec<Vec<TensorView>> = bufs
            .iter()
            .map(|(zc, hc)| {
                vec![
                    TensorView::f32(zc, &[bb, dims.zdim]),
                    TensorView::f32(hc, &[bb, dims.rdim]),
                ]
            })
            .collect();
        let outs = self.backend.exec_with_params_batch("ctrl_policy_b", ctrl, &rests)?;
        let mut xlogits = Vec::with_capacity(b * dims.x1);
        let mut llogits = Vec::with_capacity(b * dims.x1 * dims.max_locs);
        let mut values = Vec::with_capacity(b);
        for (chunk, out) in outs.into_iter().enumerate() {
            let real = (b - chunk * bb).min(bb);
            xlogits.extend_from_slice(&out[0].data[..real * dims.x1]);
            llogits.extend_from_slice(&out[1].data[..real * dims.x1 * dims.max_locs]);
            values.extend_from_slice(&out[2].data[..real]);
        }
        Ok((xlogits, llogits, values))
    }

    /// Sample one row's `(xfer, location)` action from the flat forward
    /// buffers (the shared core of [`act_batch`](Self::act_batch) and
    /// [`act_rows`](Self::act_rows)).
    #[allow(clippy::too_many_arguments)]
    fn sample_row(
        &self,
        row: usize,
        xlogits: &[f32],
        llogits: &[f32],
        values: &[f32],
        xmask: &[f32],
        loc_mask: &impl Fn(usize, usize) -> Vec<bool>,
        rng: &mut Rng,
        greedy: bool,
    ) -> ActOut {
        let dims = &self.dims;
        let noop = self.space.noop_slot();
        let xl = &xlogits[row * dims.x1..(row + 1) * dims.x1];
        // Force the NO-OP slot valid: an all-masked row (possible when
        // the dream env's mask head predicts nothing valid) must
        // degrade to "terminate" with a finite logp, not an arbitrary
        // uniform action at logp = -inf.
        let xm: Vec<bool> = xmask[row * dims.x1..(row + 1) * dims.x1]
            .iter()
            .enumerate()
            .map(|(i, &m)| i == noop || m >= 0.5)
            .collect();
        let x_lsm = masked_log_softmax(xl, &xm);
        let x = if greedy { argmax_masked(xl, &xm) } else { rng.sample_logits_masked(xl, &xm) };
        let mut logp = x_lsm[x];

        let action = if x == noop {
            Action::new(x, 0)
        } else {
            let lm = loc_mask(row, x);
            let base = (row * dims.x1 + x) * dims.max_locs;
            let ll = &llogits[base..base + dims.max_locs];
            let l_lsm = masked_log_softmax(ll, &lm);
            let l =
                if greedy { argmax_masked(ll, &lm) } else { rng.sample_logits_masked(ll, &lm) };
            logp += l_lsm[l];
            Action::new(x, l)
        };
        ActOut { action, logp, value: values[row] }
    }

    /// Run the policy program and sample per-row actions from one RNG
    /// stream (rows consume it in ascending order).
    ///
    /// `obs.xmask`: `b * x1` validity (>= 0.5 is valid); the NO-OP slot is
    /// forced valid regardless, exactly as the dream env does.
    /// `loc_mask(row, xfer)` gives the location mask for that row's chosen
    /// xfer. Any batch width is accepted (see [`forward_rows`](Self::forward_rows)).
    pub fn act_batch(
        &self,
        ctrl: &ParamStore,
        obs: &ObsBatch,
        loc_mask: impl Fn(usize, usize) -> Vec<bool>,
        rng: &mut Rng,
        greedy: bool,
    ) -> anyhow::Result<Vec<ActOut>> {
        let dims = &self.dims;
        let b = obs.z.len() / dims.zdim.max(1);
        anyhow::ensure!(
            obs.z.len() == b * dims.zdim
                && obs.h.len() == b * dims.rdim
                && obs.xmask.len() == b * dims.x1,
            "act_batch: bad obs sizes"
        );
        let (xlogits, llogits, values) = self.forward_rows(ctrl, obs.z, obs.h, b)?;
        Ok((0..b)
            .map(|row| {
                self.sample_row(row, &xlogits, &llogits, &values, obs.xmask, &loc_mask, rng, greedy)
            })
            .collect())
    }

    /// [`act_batch`](Self::act_batch) with one independent RNG stream per
    /// row — the EnvPool evaluation path, where row `i`'s sampling must
    /// not depend on which other rows are still alive. One batched
    /// forward, per-row streams.
    pub fn act_rows(
        &self,
        ctrl: &ParamStore,
        obs: &ObsBatch,
        loc_mask: impl Fn(usize, usize) -> Vec<bool>,
        rngs: &mut [Rng],
        greedy: bool,
    ) -> anyhow::Result<Vec<ActOut>> {
        let dims = &self.dims;
        let b = rngs.len();
        anyhow::ensure!(
            obs.z.len() == b * dims.zdim
                && obs.h.len() == b * dims.rdim
                && obs.xmask.len() == b * dims.x1,
            "act_rows: bad obs sizes"
        );
        let (xlogits, llogits, values) = self.forward_rows(ctrl, obs.z, obs.h, b)?;
        Ok(rngs
            .iter_mut()
            .enumerate()
            .map(|(row, rng)| {
                self.sample_row(row, &xlogits, &llogits, &values, obs.xmask, &loc_mask, rng, greedy)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_log_softmax_normalises() {
        let lsm = masked_log_softmax(&[1.0, 2.0, 3.0], &[true, false, true]);
        assert_eq!(lsm[1], f32::NEG_INFINITY);
        let p: f32 = lsm.iter().filter(|v| v.is_finite()).map(|v| v.exp()).sum();
        assert!((p - 1.0).abs() < 1e-5);
    }

    #[test]
    fn all_masked_is_neg_inf() {
        let lsm = masked_log_softmax(&[1.0, 2.0], &[false, false]);
        assert!(lsm.iter().all(|v| *v == f32::NEG_INFINITY));
    }

    #[test]
    fn argmax_respects_mask() {
        assert_eq!(argmax_masked(&[5.0, 9.0, 1.0], &[true, false, true]), 0);
    }

    #[test]
    fn all_masked_row_falls_back_to_noop() {
        // Regression (satellite): a row whose xfer mask is entirely invalid
        // must force the NO-OP slot and report a finite logp.
        let backend = crate::runtime::HostBackend::with_config(crate::runtime::HostConfig {
            max_nodes: 8,
            node_feats: 24,
            gnn_hidden: 4,
            latent: 4,
            rnn_hidden: 4,
            mdn_k: 2,
            act_emb: 2,
            ctrl_hidden: 4,
            n_xfers1: 5,
            max_locs: 6,
            b_dream: 2,
            b_wm: 2,
            seq_len: 2,
            b_ppo: 4,
            b_enc: 2,
            kernels: crate::runtime::KernelCfg::default(),
        });
        let policy = PolicyNet::new(&backend).unwrap();
        let ctrl = ParamStore::init(&backend, "ctrl", 0).unwrap();
        let z = vec![0.1f32; 2 * 4];
        let h = vec![0.0f32; 2 * 4];
        let xmask = vec![0.0f32; 2 * 5]; // every slot invalid on both rows
        let mut rng = Rng::new(3);
        let acts = policy
            .act_batch(
                &ctrl,
                &ObsBatch { z: &z, h: &h, xmask: &xmask },
                |_, _| vec![true; 6],
                &mut rng,
                false,
            )
            .unwrap();
        for a in &acts {
            assert_eq!(a.action, policy.space.noop(), "must fall back to NO-OP");
            assert!(a.logp.is_finite(), "logp must stay finite, got {}", a.logp);
            assert!((a.logp - 0.0).abs() < 1e-5, "NO-OP is the only valid slot: logp ~ ln(1)");
        }
        // Greedy path takes the same fallback.
        let acts = policy
            .act_batch(
                &ctrl,
                &ObsBatch { z: &z, h: &h, xmask: &xmask },
                |_, _| vec![true; 6],
                &mut rng,
                true,
            )
            .unwrap();
        assert!(acts.iter().all(|a| a.action == policy.space.noop() && a.logp.is_finite()));
    }
}
