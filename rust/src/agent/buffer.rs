//! Rollout storage: compact graph states, episodes, and GAE.
//!
//! States are stored sparsely (live-row features + op-edge list) because a
//! dense `[320, 320]` adjacency per step would be ~400 KiB; the dense
//! tensors are materialised only when batching into the GNN artifacts.

use crate::env::EncodedGraph;
use crate::util::Rng;

/// Sparse snapshot of one encoded environment state.
#[derive(Debug, Clone)]
pub struct CompactState {
    pub n_live: usize,
    /// `n_live * F` features (live rows only).
    pub feats: Vec<f32>,
    /// Directed op-row edges (src < dst by topological encoding).
    pub edges: Vec<(u16, u16)>,
}

impl CompactState {
    pub fn from_encoded(e: &EncodedGraph) -> Self {
        let n_live = e.mask.iter().filter(|&&m| m > 0.0).count();
        let feats = e.feats[..n_live * e.f].to_vec();
        let mut edges = Vec::new();
        for src in 0..n_live {
            for dst in 0..n_live {
                if e.adj[src * e.n + dst] > 0.0 {
                    edges.push((src as u16, dst as u16));
                }
            }
        }
        Self { n_live, feats, edges }
    }

    /// Write dense (feats, adj, mask) rows into per-sample slices of a batch.
    pub fn write_dense(
        &self,
        n: usize,
        f: usize,
        feats: &mut [f32],
        adj: &mut [f32],
        mask: &mut [f32],
    ) {
        debug_assert_eq!(feats.len(), n * f);
        debug_assert_eq!(adj.len(), n * n);
        debug_assert_eq!(mask.len(), n);
        feats.fill(0.0);
        adj.fill(0.0);
        mask.fill(0.0);
        let live = self.n_live.min(n);
        feats[..live * f].copy_from_slice(&self.feats[..live * f]);
        mask[..live].fill(1.0);
        for &(s, d) in &self.edges {
            let (s, d) = (s as usize, d as usize);
            if s < n && d < n {
                adj[s * n + d] = 1.0;
            }
        }
    }
}

/// One environment episode: `states.len() == actions.len() + 1`.
#[derive(Debug, Clone, Default)]
pub struct Episode {
    pub states: Vec<CompactState>,
    /// Per-state xfer validity mask (f32, length X+1), aligned with states.
    pub xmasks: Vec<Vec<f32>>,
    pub actions: Vec<(u16, u16)>,
    pub rewards: Vec<f32>,
    /// 1.0 on the step that terminated the episode.
    pub dones: Vec<f32>,
    /// Latents per state, filled in by the encoder pass (empty until then).
    pub z: Vec<Vec<f32>>,
    /// Version of the policy params the episode was collected under
    /// (`ParamStore::version`; 0 = the random collection policy). A
    /// learner batch must never mix versions — see
    /// [`uniform_policy_version`] and `PpoBuffer::note_version`.
    pub policy_version: u64,
}

impl Episode {
    pub fn len(&self) -> usize {
        self.actions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    pub fn total_reward(&self) -> f32 {
        self.rewards.iter().sum()
    }
}

/// The single policy version a batch of episodes was collected under.
/// Errors if the set is empty or spans two versions — the guard the
/// async pipeline's learner stages run before assembling any training
/// batch (a schedule must never let trajectories from two policy
/// versions meet in one update).
pub fn uniform_policy_version(episodes: &[Episode]) -> anyhow::Result<u64> {
    let first = episodes
        .first()
        .ok_or_else(|| anyhow::anyhow!("no episodes to take a policy version from"))?
        .policy_version;
    for ep in episodes {
        anyhow::ensure!(
            ep.policy_version == first,
            "refusing to mix trajectories from policy versions {first} and {} in one batch",
            ep.policy_version
        );
    }
    Ok(first)
}

/// Generalised Advantage Estimation over one episode's rewards/values.
/// `values` has length T+1 (bootstrap value of the final state).
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    dones: &[f32],
    gamma: f32,
    lam: f32,
) -> (Vec<f32>, Vec<f32>) {
    let t_len = rewards.len();
    assert_eq!(values.len(), t_len + 1);
    assert_eq!(dones.len(), t_len);
    let mut adv = vec![0.0f32; t_len];
    let mut last = 0.0f32;
    for t in (0..t_len).rev() {
        let nonterminal = 1.0 - dones[t];
        let delta = rewards[t] + gamma * values[t + 1] * nonterminal - values[t];
        last = delta + gamma * lam * nonterminal * last;
        adv[t] = last;
    }
    let returns: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, returns)
}

/// Sample `count` sequence windows of length `seq` (start indices) from
/// episodes with at least 1 step; pads shorter episodes via the valid mask.
pub fn sample_windows<'a>(
    episodes: &'a [Episode],
    count: usize,
    rng: &mut Rng,
) -> Vec<(&'a Episode, usize)> {
    let usable: Vec<&Episode> = episodes.iter().filter(|e| !e.is_empty()).collect();
    assert!(!usable.is_empty(), "no usable episodes");
    (0..count)
        .map(|_| {
            let ep = usable[rng.below(usable.len())];
            let start = if ep.len() <= 1 { 0 } else { rng.below(ep.len()) };
            (ep, start)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::StateEncoder;
    use crate::graph::{GraphBuilder, PadMode};

    #[test]
    fn compact_round_trip() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        let g = b.finish();
        let enc = StateEncoder::new(320, 32);
        let e = enc.encode(&g);
        let compact = CompactState::from_encoded(&e);
        assert_eq!(compact.n_live, 2);
        assert_eq!(compact.edges, vec![(0, 1)]);

        let mut feats = vec![0.0; 320 * 32];
        let mut adj = vec![0.0; 320 * 320];
        let mut mask = vec![0.0; 320];
        compact.write_dense(320, 32, &mut feats, &mut adj, &mut mask);
        assert_eq!(feats, e.feats);
        assert_eq!(adj, e.adj);
        assert_eq!(mask, e.mask);
    }

    #[test]
    fn gae_terminal_cuts_bootstrap() {
        // Single step, done: advantage = r - v0.
        let (adv, ret) = gae(&[1.0], &[0.5, 9.0], &[1.0], 0.99, 0.95);
        assert!((adv[0] - 0.5).abs() < 1e-6);
        assert!((ret[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gae_propagates_back() {
        let rewards = [0.0, 0.0, 1.0];
        let values = [0.0, 0.0, 0.0, 0.0];
        let dones = [0.0, 0.0, 1.0];
        let (adv, _) = gae(&rewards, &values, &dones, 0.9, 1.0);
        assert!(adv[0] > 0.0 && adv[0] < adv[1] && adv[1] < adv[2]);
        assert!((adv[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn policy_version_defaults_to_random_policy() {
        assert_eq!(Episode::default().policy_version, 0, "0 tags the random collection policy");
    }

    #[test]
    fn uniform_policy_version_accepts_one_version_only() {
        let mut a = Episode::default();
        a.policy_version = 3;
        let mut b = Episode::default();
        b.policy_version = 3;
        assert_eq!(uniform_policy_version(&[a.clone(), b.clone()]).unwrap(), 3);
        // Boundary: the very first episode of the *next* version must be
        // rejected from the previous version's batch.
        b.policy_version = 4;
        let err = uniform_policy_version(&[a, b]).unwrap_err();
        assert!(err.to_string().contains("refusing to mix"), "got: {err}");
        assert!(uniform_policy_version(&[]).is_err(), "empty batch has no version");
    }

    #[test]
    fn windows_sample_within_bounds() {
        let mut ep = Episode::default();
        for _ in 0..5 {
            ep.actions.push((0, 0));
            ep.rewards.push(0.0);
            ep.dones.push(0.0);
        }
        let eps = vec![ep];
        let mut rng = Rng::new(0);
        for (e, start) in sample_windows(&eps, 20, &mut rng) {
            assert!(start < e.len());
        }
    }
}
