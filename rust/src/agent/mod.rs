//! Agents: rollout storage, the random data-collection agent, masked
//! policy acting over the controller artifacts, and the PPO update driver.

pub mod buffer;
pub mod policy;
pub mod ppo;
pub mod random;

pub use buffer::{gae, CompactState, Episode};
pub use policy::{act_batch, masked_log_softmax, ActOut, PolicyDims};
pub use ppo::{ppo_update, PpoBuffer, PpoCfg, PpoStats};
pub use random::{collect_one, collect_random_episodes, collect_random_pool};
