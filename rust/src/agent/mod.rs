//! Agents: rollout storage, the random data-collection agent, the typed
//! action space, masked policy acting over the controller programs, and
//! the PPO update driver.

pub mod action;
pub mod buffer;
pub mod policy;
pub mod ppo;
pub mod random;

pub use action::{Action, ActionSpace};
pub use buffer::{gae, uniform_policy_version, CompactState, Episode};
pub use policy::{masked_log_softmax, ActOut, ObsBatch, PolicyDims, PolicyNet};
pub use ppo::{ppo_update, PpoBatch, PpoBuffer, PpoCfg, PpoStats};
pub use random::{collect_one, collect_random_episodes, collect_random_pool};
