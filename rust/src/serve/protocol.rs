//! Wire protocol of the `rlflow serve` daemon.
//!
//! Newline-delimited JSON: every request is one line, every response is
//! one line (the writer escapes embedded newlines, so a compact-encoded
//! [`Json`] document never spans lines). Graph payloads reuse the
//! ONNX-style model format ([`crate::graph::onnx`]); framing reuses
//! [`crate::util::json`] under serve-specific limits ([`MAX_LINE_BYTES`],
//! [`MAX_WIRE_DEPTH`]) so an adversarial peer can neither exhaust the
//! parser stack nor buffer unbounded input.
//!
//! # Requests
//!
//! ```text
//! {"type":"optimize","graph":{<onnx model>},"method":"taso",
//!  "alpha":1.05,"beam":4,"depth":80,
//!  "cost_noise":0.0,"noise_seed":0,"timeout_ms":60000}
//! {"type":"optimize","graph":{...},"method":"greedy","max_steps":100}
//! {"type":"stats"}
//! {"type":"ping"}
//! {"type":"shutdown"}
//! ```
//!
//! # Responses
//!
//! ```text
//! {"type":"result","provenance":"fresh|cache|coalesced",
//!  "elapsed_s":3.21,"result":{<deterministic payload>}}
//! {"type":"stats","stats":{...}}
//! {"type":"pong"}
//! {"type":"ok","detail":"draining"}
//! {"type":"error","code":"overloaded","message":"queue full (64 queued)"}
//! ```
//!
//! # Determinism contract
//!
//! The `result` object is byte-deterministic for a given (config
//! fingerprint, canonical root hash): object keys are `BTreeMap`-ordered,
//! floats print shortest-round-trip, and every field it contains is either
//! part of the memoised [`SearchLog`] or derived from it. Fields that
//! legitimately vary between servings — wall-clock `elapsed_s` and the
//! cache `provenance` — live in the envelope *next to* `result`, never
//! inside it. This is what makes the warm-restart contract testable: the
//! same request served fresh, from the in-memory memo, or from a
//! restarted daemon's replayed disk cache compares equal on
//! `result` bytes.

use crate::graph::{onnx, Graph};
use crate::search::SearchLog;
use crate::util::json::{parse_with_limits, Json};

/// Maximum bytes in one request or response line (8 MiB — the largest zoo
/// graph exports to well under 1 MiB).
pub const MAX_LINE_BYTES: usize = 8 << 20;

/// Maximum JSON nesting depth accepted on the wire (graph models nest a
/// constant handful of levels).
pub const MAX_WIRE_DEPTH: usize = 32;

/// Ceiling on client-requested timeouts (one day, in milliseconds).
pub const MAX_TIMEOUT_MS: u64 = 86_400_000;

/// Search algorithm + knobs requested for one optimisation.
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    /// TF-style greedy descent with a step budget.
    Greedy {
        /// Maximum substitutions applied.
        max_steps: usize,
    },
    /// TASO-style relaxed beam search.
    Taso {
        /// Relaxation factor (candidates below `alpha * best` survive).
        alpha: f64,
        /// Beam width.
        beam: usize,
        /// Maximum search depth.
        depth: usize,
    },
}

impl Method {
    /// Wire name of the algorithm ("greedy" / "taso").
    pub fn name(&self) -> &'static str {
        match self {
            Method::Greedy { .. } => "greedy",
            Method::Taso { .. } => "taso",
        }
    }
}

/// One graph-optimisation request: the payload of `{"type":"optimize"}`.
#[derive(Debug, Clone)]
pub struct OptimizeRequest {
    /// The computation graph to optimise.
    pub graph: Graph,
    /// Display name echoed into the response payload's exported graph.
    pub graph_name: String,
    /// Search method and knobs.
    pub method: Method,
    /// Cost-model measurement-noise std-dev (0 = deterministic model).
    pub cost_noise: f64,
    /// Seed of the noise field (meaningful when `cost_noise > 0`).
    pub noise_seed: u64,
    /// Per-request wall-clock budget; `None` uses the server default.
    pub timeout_ms: Option<u64>,
}

/// A decoded request line.
#[derive(Debug, Clone)]
pub enum Request {
    /// Optimise a graph (boxed: the graph dominates the enum size).
    Optimize(Box<OptimizeRequest>),
    /// Return the daemon's counters.
    Stats,
    /// Liveness probe.
    Ping,
    /// Drain in-flight work and exit.
    Shutdown,
}

/// Where a served result came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    /// A live search ran for this request.
    Fresh,
    /// Answered from the persistent [`crate::search::SearchCache`]
    /// (in-memory or replayed from disk).
    Cache,
    /// Attached to another request's in-flight search for the same
    /// (fingerprint, root hash) and received its result.
    Coalesced,
}

impl Provenance {
    /// Wire string ("fresh" / "cache" / "coalesced").
    pub fn as_str(self) -> &'static str {
        match self {
            Provenance::Fresh => "fresh",
            Provenance::Cache => "cache",
            Provenance::Coalesced => "coalesced",
        }
    }

    /// Parse a wire string back into a [`Provenance`].
    pub fn parse(s: &str) -> anyhow::Result<Provenance> {
        Ok(match s {
            "fresh" => Provenance::Fresh,
            "cache" => Provenance::Cache,
            "coalesced" => Provenance::Coalesced,
            other => anyhow::bail!("unknown provenance '{other}'"),
        })
    }
}

/// Typed error classes the daemon reports. Every failure mode maps to one
/// of these — a client never sees a hang or a dropped connection for a
/// condition the daemon detected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The bounded request queue is full: load was shed, try again later.
    Overloaded,
    /// The per-request wall-clock budget elapsed before a result was
    /// ready. The underlying search keeps running and still warms the
    /// cache — a retry of the same request typically hits.
    Timeout,
    /// The request line failed to parse or validate.
    BadRequest,
    /// The daemon is draining for shutdown and admits no new searches.
    ShuttingDown,
    /// The search failed for an unexpected internal reason.
    Internal,
}

impl ErrorCode {
    /// Wire string of the error class.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Timeout => "timeout",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }

    /// Parse a wire string back into an [`ErrorCode`].
    pub fn parse(s: &str) -> anyhow::Result<ErrorCode> {
        Ok(match s {
            "overloaded" => ErrorCode::Overloaded,
            "timeout" => ErrorCode::Timeout,
            "bad_request" => ErrorCode::BadRequest,
            "shutting_down" => ErrorCode::ShuttingDown,
            "internal" => ErrorCode::Internal,
            other => anyhow::bail!("unknown error code '{other}'"),
        })
    }
}

/// A decoded response line.
#[derive(Debug, Clone)]
pub enum Response {
    /// An optimisation result: the deterministic payload plus the
    /// per-serving envelope (provenance, server-side wall clock).
    Result {
        /// Deterministic payload (see [`result_payload`]).
        payload: Json,
        /// Where the result came from.
        provenance: Provenance,
        /// Server-side seconds spent on this serving.
        elapsed_s: f64,
    },
    /// Daemon counters (see [`super::stats::ServeStats::to_json`]).
    Stats(Json),
    /// Reply to `ping`.
    Pong,
    /// Acknowledgement of a control request.
    Ok(String),
    /// A typed failure.
    Error {
        /// The error class.
        code: ErrorCode,
        /// Human-readable detail.
        message: String,
    },
}

/// The deterministic `result` object for a served search: exported graph,
/// endpoint costs, improvement and the applied-substitution trail. Every
/// field is memoised state — nothing here may depend on wall clock, cache
/// temperature or thread count (see the module docs' determinism
/// contract; `tests/serve_core.rs` pins it).
pub fn result_payload(graph: &Graph, name: &str, log: &SearchLog) -> anyhow::Result<Json> {
    let mut p = Json::obj();
    p.set("graph", onnx::export(graph, name)?);
    p.set("initial_ms", Json::Num(log.initial_ms));
    p.set("final_ms", Json::Num(log.final_ms));
    p.set("improvement_pct", Json::Num(log.improvement_pct()));
    p.set("graphs_explored", Json::Num(log.graphs_explored as f64));
    p.set(
        "steps",
        Json::Arr(
            log.steps
                .iter()
                .map(|(rule, ms)| Json::Arr(vec![Json::Str(rule.clone()), Json::Num(*ms)]))
                .collect(),
        ),
    );
    Ok(p)
}

/// Encode an optimise request as one wire line (no trailing newline).
pub fn encode_optimize(req: &OptimizeRequest) -> anyhow::Result<String> {
    let mut j = Json::obj();
    j.set("type", Json::Str("optimize".into()));
    j.set("graph", onnx::export(&req.graph, &req.graph_name)?);
    j.set("method", Json::Str(req.method.name().into()));
    match req.method {
        Method::Greedy { max_steps } => {
            j.set("max_steps", Json::Num(max_steps as f64));
        }
        Method::Taso { alpha, beam, depth } => {
            j.set("alpha", Json::Num(alpha));
            j.set("beam", Json::Num(beam as f64));
            j.set("depth", Json::Num(depth as f64));
        }
    }
    if req.cost_noise > 0.0 {
        j.set("cost_noise", Json::Num(req.cost_noise));
        j.set("noise_seed", Json::Num(req.noise_seed as f64));
    }
    if let Some(t) = req.timeout_ms {
        j.set("timeout_ms", Json::Num(t as f64));
    }
    Ok(j.to_string_compact())
}

/// Encode a control request (`stats` / `ping` / `shutdown`) as one line.
pub fn encode_control(kind: &str) -> String {
    let mut j = Json::obj();
    j.set("type", Json::Str(kind.into()));
    j.to_string_compact()
}

/// Decode one request line. Enforces the wire limits, full JSON validity,
/// graph well-formedness (via [`onnx::import`]) and knob ranges; any
/// violation is an `Err` the server maps to a `bad_request` response.
pub fn decode_request(line: &str) -> anyhow::Result<Request> {
    let j = parse_with_limits(line, MAX_LINE_BYTES, MAX_WIRE_DEPTH)?;
    let ty = j.get("type")?.as_str()?;
    match ty {
        "stats" => Ok(Request::Stats),
        "ping" => Ok(Request::Ping),
        "shutdown" => Ok(Request::Shutdown),
        "optimize" => {
            let graph_j = j.get("graph")?;
            let graph = onnx::import(graph_j)?;
            let graph_name = match graph_j.opt("graph_name") {
                Some(n) => n.as_str()?.to_string(),
                None => "graph".to_string(),
            };
            let method_name = match j.opt("method") {
                Some(m) => m.as_str()?,
                None => "taso",
            };
            let method = match method_name {
                "greedy" => {
                    let max_steps = match j.opt("max_steps") {
                        Some(v) => v.as_usize()?,
                        None => 100,
                    };
                    anyhow::ensure!(
                        (1..=100_000).contains(&max_steps),
                        "max_steps {} out of range [1, 100000]",
                        max_steps
                    );
                    Method::Greedy { max_steps }
                }
                "taso" => {
                    let alpha = match j.opt("alpha") {
                        Some(v) => v.as_f64()?,
                        None => 1.05,
                    };
                    anyhow::ensure!(
                        alpha.is_finite() && (1.0..=16.0).contains(&alpha),
                        "alpha {} out of range [1, 16]",
                        alpha
                    );
                    let beam = match j.opt("beam") {
                        Some(v) => v.as_usize()?,
                        None => 4,
                    };
                    anyhow::ensure!(
                        (1..=256).contains(&beam),
                        "beam {} out of range [1, 256]",
                        beam
                    );
                    let depth = match j.opt("depth") {
                        Some(v) => v.as_usize()?,
                        None => 80,
                    };
                    anyhow::ensure!(
                        (1..=4096).contains(&depth),
                        "depth {} out of range [1, 4096]",
                        depth
                    );
                    Method::Taso { alpha, beam, depth }
                }
                other => anyhow::bail!("unknown method '{other}' (greedy|taso)"),
            };
            let cost_noise = match j.opt("cost_noise") {
                Some(v) => v.as_f64()?,
                None => 0.0,
            };
            anyhow::ensure!(
                cost_noise.is_finite() && (0.0..=1.0).contains(&cost_noise),
                "cost_noise {} out of range [0, 1]",
                cost_noise
            );
            let noise_seed = match j.opt("noise_seed") {
                Some(v) => v.as_usize()? as u64,
                None => 0,
            };
            let timeout_ms = match j.opt("timeout_ms") {
                Some(v) => {
                    let t = v.as_usize()? as u64;
                    anyhow::ensure!(
                        t >= 1 && t <= MAX_TIMEOUT_MS,
                        "timeout_ms {} out of range [1, {}]",
                        t,
                        MAX_TIMEOUT_MS
                    );
                    Some(t)
                }
                None => None,
            };
            Ok(Request::Optimize(Box::new(OptimizeRequest {
                graph,
                graph_name,
                method,
                cost_noise,
                noise_seed,
                timeout_ms,
            })))
        }
        other => anyhow::bail!("unknown request type '{other}'"),
    }
}

impl Response {
    /// Shorthand for an error response.
    pub fn error(code: ErrorCode, message: impl Into<String>) -> Response {
        Response::Error { code, message: message.into() }
    }

    /// Encode as one wire line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut j = Json::obj();
        match self {
            Response::Result { payload, provenance, elapsed_s } => {
                j.set("type", Json::Str("result".into()));
                j.set("provenance", Json::Str(provenance.as_str().into()));
                j.set("elapsed_s", Json::Num(*elapsed_s));
                j.set("result", payload.clone());
            }
            Response::Stats(stats) => {
                j.set("type", Json::Str("stats".into()));
                j.set("stats", stats.clone());
            }
            Response::Pong => {
                j.set("type", Json::Str("pong".into()));
            }
            Response::Ok(detail) => {
                j.set("type", Json::Str("ok".into()));
                j.set("detail", Json::Str(detail.clone()));
            }
            Response::Error { code, message } => {
                j.set("type", Json::Str("error".into()));
                j.set("code", Json::Str(code.as_str().into()));
                j.set("message", Json::Str(message.clone()));
            }
        }
        j.to_string_compact()
    }

    /// Decode one response line (the client half of the protocol).
    pub fn decode(line: &str) -> anyhow::Result<Response> {
        let j = parse_with_limits(line, MAX_LINE_BYTES, MAX_WIRE_DEPTH)?;
        Ok(match j.get("type")?.as_str()? {
            "result" => Response::Result {
                payload: j.get("result")?.clone(),
                provenance: Provenance::parse(j.get("provenance")?.as_str()?)?,
                elapsed_s: j.get("elapsed_s")?.as_f64()?,
            },
            "stats" => Response::Stats(j.get("stats")?.clone()),
            "pong" => Response::Pong,
            "ok" => Response::Ok(j.get("detail")?.as_str()?.to_string()),
            "error" => Response::Error {
                code: ErrorCode::parse(j.get("code")?.as_str()?)?,
                message: j.get("message")?.as_str()?.to_string(),
            },
            other => anyhow::bail!("unknown response type '{other}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::canonical_hash;

    fn tiny_graph() -> Graph {
        let mut b = crate::graph::GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let _ = b.relu(x).unwrap();
        b.finish()
    }

    #[test]
    fn optimize_request_round_trips() {
        let g = tiny_graph();
        let req = OptimizeRequest {
            graph: g.clone(),
            graph_name: "tiny".into(),
            method: Method::Taso { alpha: 1.05, beam: 4, depth: 80 },
            cost_noise: 0.0,
            noise_seed: 0,
            timeout_ms: Some(5000),
        };
        let line = encode_optimize(&req).unwrap();
        assert!(!line.contains('\n'), "wire lines must be single-line");
        match decode_request(&line).unwrap() {
            Request::Optimize(d) => {
                assert_eq!(canonical_hash(&d.graph), canonical_hash(&g));
                assert_eq!(d.graph_name, "tiny");
                assert_eq!(d.method, Method::Taso { alpha: 1.05, beam: 4, depth: 80 });
                assert_eq!(d.timeout_ms, Some(5000));
            }
            other => panic!("decoded wrong variant: {other:?}"),
        }
    }

    #[test]
    fn control_requests_round_trip() {
        assert!(matches!(decode_request(&encode_control("stats")).unwrap(), Request::Stats));
        assert!(matches!(decode_request(&encode_control("ping")).unwrap(), Request::Ping));
        assert!(matches!(decode_request(&encode_control("shutdown")).unwrap(), Request::Shutdown));
    }

    #[test]
    fn responses_round_trip() {
        let e = Response::error(ErrorCode::Overloaded, "queue full");
        match Response::decode(&e.encode()).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::Overloaded);
                assert_eq!(message, "queue full");
            }
            other => panic!("decoded wrong variant: {other:?}"),
        }
        match Response::decode(&Response::Pong.encode()).unwrap() {
            Response::Pong => {}
            other => panic!("decoded wrong variant: {other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_errors_not_panics() {
        assert!(decode_request("").is_err());
        assert!(decode_request("{").is_err());
        assert!(decode_request("{\"type\":\"warp\"}").is_err());
        assert!(decode_request("{\"type\":\"optimize\"}").is_err(), "missing graph");
        // Out-of-range knobs are rejected, not clamped.
        let g = tiny_graph();
        let line = encode_optimize(&OptimizeRequest {
            graph: g,
            graph_name: "g".into(),
            method: Method::Taso { alpha: 1.05, beam: 4, depth: 80 },
            cost_noise: 0.0,
            noise_seed: 0,
            timeout_ms: None,
        })
        .unwrap();
        let bad = line.replace("\"alpha\":1.05", "\"alpha\":99");
        assert!(decode_request(&bad).is_err(), "alpha out of range must be rejected");
    }

    #[test]
    fn result_payload_is_envelope_free() {
        let g = tiny_graph();
        let log = crate::search::SearchLog {
            steps: vec![("fuse".into(), 1.25)],
            initial_ms: 2.0,
            final_ms: 1.25,
            elapsed_s: 0.5,
            graphs_explored: 7,
            table_size: 9,
            memo_hits: 3,
            threads: 8,
            from_cache: true,
        };
        let p = result_payload(&g, "tiny", &log).unwrap();
        let bytes = p.to_string_compact();
        // Per-serving fields must not leak into the deterministic payload.
        assert!(!bytes.contains("elapsed"), "payload must not carry wall clock");
        assert!(!bytes.contains("from_cache"), "payload must not carry provenance");
        assert!(!bytes.contains("threads"), "payload must not carry thread count");
        assert_eq!(p.get("graphs_explored").unwrap().as_usize().unwrap(), 7);
    }
}
