//! The socket-free heart of the daemon: [`ServeCore`] owns the shared
//! [`SearchCache`], its disk [`Persister`], the in-flight coalescing map
//! and every request-level counter. The TCP layer ([`super::server`]) is
//! a thin shell over this type, which is what lets `tests/serve_core.rs`
//! pin coalescing, persistence and provenance semantics without opening
//! a socket.
//!
//! # Request lifecycle
//!
//! `optimize` keys the request by `(config fingerprint, canonical root
//! hash)` — the same key the cache and the disk log use — then elects a
//! role under the in-flight map's lock:
//!
//! * **Leader** — no identical request is running: registers a
//!   [`Flight`], runs the cached search (which does its own memo
//!   lookup/store), publishes the result to the flight, appends fresh
//!   results to disk. Provenance is `cache` when the memo answered,
//!   `fresh` when a live search ran.
//! * **Follower** — an identical request is in flight: blocks on the
//!   leader's flight (with the request's deadline) and returns the
//!   shared result with provenance `coalesced`. N concurrent identical
//!   requests execute exactly one search (pinned by test).
//!
//! A leader that panics or errors resolves its flight with an error on
//! unwind (via a drop guard), so followers never hang on an abandoned
//! flight — every failure mode surfaces as a typed error.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::cost::{CostModel, DeviceProfile};
use crate::graph::canonical_hash;
use crate::graph::Graph;
use crate::search::{
    greedy_fingerprint, greedy_optimise_cached, taso_fingerprint, taso_optimise_cached,
    CacheStats, SearchCache, SearchLog, TasoConfig,
};
use crate::util::json::Json;
use crate::xfer::library::standard_library;
use crate::xfer::RuleSet;

use super::persist::{CacheEntry, Persister};
use super::protocol::{result_payload, Method, OptimizeRequest, Provenance};
use super::stats::{LatencyAgg, ServeStats};

/// Knobs of the serve core (the TCP layer adds its own on top).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory for the persistent cache (`None` = in-memory only).
    pub cache_dir: Option<PathBuf>,
    /// Result-memo bound of the shared [`SearchCache`].
    pub max_results: usize,
    /// Cost-memo bound of the shared [`SearchCache`].
    pub max_cost_entries: usize,
    /// Fresh results between automatic snapshot compactions.
    pub snapshot_every: usize,
    /// Worker threads per search (0 = all cores); results are
    /// bit-identical for every value, so this is purely a resource knob.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            cache_dir: None,
            max_results: 4096,
            max_cost_entries: 1 << 20,
            snapshot_every: 64,
            threads: 0,
        }
    }
}

/// A finished serving: the optimised graph plus its memoised log.
#[derive(Debug)]
pub struct Served {
    /// The optimised graph.
    pub graph: Graph,
    /// The search log as memoised (followers see the leader's log).
    pub log: SearchLog,
}

/// One request's result envelope.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Shared result (followers hold the same allocation as the leader).
    pub served: Arc<Served>,
    /// Where it came from.
    pub provenance: Provenance,
    /// Wall-clock seconds this request spent inside the core.
    pub elapsed_s: f64,
}

impl Outcome {
    /// The deterministic response payload for this serving (see
    /// [`result_payload`]).
    pub fn payload(&self, name: &str) -> anyhow::Result<Json> {
        result_payload(&self.served.graph, name, &self.served.log)
    }
}

/// Typed failures of [`ServeCore::optimize`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline elapsed while waiting on a coalesced
    /// search. The leader keeps running and still warms the cache.
    Timeout,
    /// The search failed (message preserved for the error response).
    Failed(String),
}

#[derive(Default)]
struct Flight {
    slot: Mutex<Option<Result<Arc<Served>, String>>>,
    done: Condvar,
}

/// Resolves the flight and unregisters it exactly once — including on
/// unwind, so a panicking leader releases its followers with an error
/// instead of stranding them.
struct FlightGuard<'a> {
    core: &'a ServeCore,
    key: (u64, u64),
    flight: Arc<Flight>,
    resolved: bool,
}

impl FlightGuard<'_> {
    fn finish(&mut self, result: Result<Arc<Served>, String>) {
        if self.resolved {
            return;
        }
        self.resolved = true;
        if let Ok(mut slot) = self.flight.slot.lock() {
            *slot = Some(result);
        }
        self.flight.done.notify_all();
        if let Ok(mut map) = self.core.inflight.lock() {
            map.remove(&self.key);
        }
    }

    fn resolve(mut self, result: Result<Arc<Served>, String>) {
        self.finish(result);
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.finish(Err("search aborted".to_string()));
    }
}

/// The daemon's shared state. `Sync`: one instance is shared by every
/// worker and connection thread behind an `Arc`.
pub struct ServeCore {
    rules: RuleSet,
    device: DeviceProfile,
    cache: Arc<SearchCache>,
    persist: Option<Mutex<Persister>>,
    inflight: Mutex<HashMap<(u64, u64), Arc<Flight>>>,
    threads: usize,
    prior: CacheStats,
    replayed: usize,

    requests: AtomicU64,
    fresh_searches: AtomicU64,
    served_from_cache: AtomicU64,
    coalesced: AtomicU64,
    rejected_overload: AtomicU64,
    timeouts: AtomicU64,
    bad_requests: AtomicU64,
    in_flight: AtomicUsize,
    latency: Mutex<LatencyAgg>,
}

impl ServeCore {
    /// Build a core, replaying the persistent cache when `cfg.cache_dir`
    /// is set: a warm-restarted core answers previously-served requests
    /// bit-identically from the replayed memo.
    pub fn open(cfg: &ServeConfig) -> anyhow::Result<ServeCore> {
        let cache = Arc::new(SearchCache::with_capacity(cfg.max_results, cfg.max_cost_entries));
        let mut prior = CacheStats::default();
        let mut replayed = 0usize;
        let persist = match &cfg.cache_dir {
            Some(dir) => {
                let (p, replay) = Persister::open(dir, cfg.snapshot_every)?;
                for e in &replay.entries {
                    cache.store_hashed(e.fp, e.root, &e.graph, &e.log);
                }
                replayed = replay.entries.len();
                prior = replay.prior;
                Some(Mutex::new(p))
            }
            None => None,
        };
        Ok(ServeCore {
            rules: standard_library(),
            device: DeviceProfile::rtx2070(),
            cache,
            persist,
            inflight: Mutex::new(HashMap::new()),
            threads: cfg.threads,
            prior,
            replayed,
            requests: AtomicU64::new(0),
            fresh_searches: AtomicU64::new(0),
            served_from_cache: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            rejected_overload: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
            latency: Mutex::new(LatencyAgg::default()),
        })
    }

    /// Results replayed from disk at startup (0 without a cache dir).
    pub fn replayed(&self) -> usize {
        self.replayed
    }

    /// The shared search cache (exposed for tests and the CLI).
    pub fn cache(&self) -> &SearchCache {
        &self.cache
    }

    /// Serve one optimisation request; `deadline` bounds how long the
    /// caller is willing to wait (the admission layer derives it from the
    /// request's `timeout_ms`). See the module docs for the
    /// leader/follower lifecycle.
    pub fn optimize(
        &self,
        req: &OptimizeRequest,
        deadline: Option<Instant>,
    ) -> Result<Outcome, ServeError> {
        let t0 = Instant::now();
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        let out = self.optimize_inner(req, deadline);
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        let elapsed_s = t0.elapsed().as_secs_f64();
        match out {
            Ok((served, provenance)) => {
                if let Ok(mut agg) = self.latency.lock() {
                    agg.record(elapsed_s);
                }
                Ok(Outcome { served, provenance, elapsed_s })
            }
            Err(e) => {
                if e == ServeError::Timeout {
                    self.timeouts.fetch_add(1, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    fn optimize_inner(
        &self,
        req: &OptimizeRequest,
        deadline: Option<Instant>,
    ) -> Result<(Arc<Served>, Provenance), ServeError> {
        let cost = self.cost_model(req);
        let root_hash = canonical_hash(&req.graph);
        let fp = match req.method {
            Method::Greedy { max_steps } => greedy_fingerprint(&cost, &self.rules, max_steps),
            Method::Taso { alpha, beam, depth } => taso_fingerprint(
                &cost,
                &self.rules,
                &TasoConfig { alpha, beam, depth, threads: self.threads },
            ),
        };
        let key = (fp, root_hash);

        enum Role {
            Leader(Arc<Flight>),
            Follower(Arc<Flight>),
        }
        let role = {
            let mut map = self.inflight.lock().expect("serve inflight map poisoned");
            match map.get(&key) {
                Some(f) => Role::Follower(Arc::clone(f)),
                None => {
                    let f = Arc::new(Flight::default());
                    map.insert(key, Arc::clone(&f));
                    Role::Leader(f)
                }
            }
        };

        match role {
            Role::Follower(flight) => {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                self.wait_flight(&flight, deadline).map(|s| (s, Provenance::Coalesced))
            }
            Role::Leader(flight) => {
                let guard = FlightGuard { core: self, key, flight, resolved: false };
                let (graph, log) = match req.method {
                    Method::Greedy { max_steps } => greedy_optimise_cached(
                        &req.graph,
                        &self.rules,
                        &cost,
                        max_steps,
                        self.threads,
                        &self.cache,
                    ),
                    Method::Taso { alpha, beam, depth } => taso_optimise_cached(
                        &req.graph,
                        &self.rules,
                        &cost,
                        &TasoConfig { alpha, beam, depth, threads: self.threads },
                        &self.cache,
                    ),
                };
                let provenance = if log.from_cache {
                    self.served_from_cache.fetch_add(1, Ordering::Relaxed);
                    Provenance::Cache
                } else {
                    self.fresh_searches.fetch_add(1, Ordering::Relaxed);
                    Provenance::Fresh
                };
                let served = Arc::new(Served { graph, log });
                // Release followers before the (possibly slow) disk append.
                guard.resolve(Ok(Arc::clone(&served)));
                if provenance == Provenance::Fresh {
                    self.persist_fresh(fp, root_hash, &served);
                }
                Ok((served, provenance))
            }
        }
    }

    fn wait_flight(
        &self,
        flight: &Flight,
        deadline: Option<Instant>,
    ) -> Result<Arc<Served>, ServeError> {
        let mut slot = flight.slot.lock().expect("serve flight poisoned");
        loop {
            if let Some(result) = slot.as_ref() {
                return match result {
                    Ok(s) => Ok(Arc::clone(s)),
                    Err(msg) => Err(ServeError::Failed(msg.clone())),
                };
            }
            match deadline {
                None => {
                    slot = flight.done.wait(slot).expect("serve flight poisoned");
                }
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(ServeError::Timeout);
                    }
                    let (s, _) = flight
                        .done
                        .wait_timeout(slot, d - now)
                        .expect("serve flight poisoned");
                    slot = s;
                }
            }
        }
    }

    fn cost_model(&self, req: &OptimizeRequest) -> CostModel {
        let cost = CostModel::new(self.device);
        if req.cost_noise > 0.0 {
            cost.with_noise(req.cost_noise, req.noise_seed)
        } else {
            cost
        }
    }

    fn persist_fresh(&self, fp: u64, root: u64, served: &Served) {
        let Some(persist) = &self.persist else { return };
        let mut log = served.log.clone();
        log.elapsed_s = 0.0;
        log.from_cache = false;
        let entry = CacheEntry { fp, root, graph: served.graph.clone(), log };
        let mut p = persist.lock().expect("serve persister poisoned");
        match p.append(&entry) {
            Ok(true) => {
                if let Err(e) = self.snapshot_locked(&mut p) {
                    eprintln!("serve: snapshot failed: {e}");
                }
            }
            Ok(false) => {}
            Err(e) => eprintln!("serve: cache append failed: {e}"),
        }
    }

    fn snapshot_locked(&self, p: &mut Persister) -> anyhow::Result<()> {
        let entries: Vec<CacheEntry> = self
            .cache
            .snapshot_results()
            .into_iter()
            .map(|(fp, root, graph, log)| CacheEntry { fp, root, graph, log })
            .collect();
        p.snapshot(&entries, &self.cache_stats())
    }

    /// Force a compacted snapshot now (shutdown path; no-op without a
    /// cache dir).
    pub fn flush(&self) -> anyhow::Result<()> {
        if let Some(persist) = &self.persist {
            let mut p = persist.lock().expect("serve persister poisoned");
            self.snapshot_locked(&mut p)?;
        }
        Ok(())
    }

    /// Lifetime cache counters: this process's [`SearchCache`] counters
    /// plus the totals persisted by previous processes on the same cache
    /// dir.
    pub fn cache_stats(&self) -> CacheStats {
        let s = self.cache.stats();
        CacheStats {
            result_hits: self.prior.result_hits + s.result_hits,
            result_misses: self.prior.result_misses + s.result_misses,
            evictions: self.prior.evictions + s.evictions,
            result_entries: s.result_entries,
            cost_entries: s.cost_entries,
        }
    }

    /// Count one shed request (the admission layer owns the queue, the
    /// core owns the counter so `stats` has a single source).
    pub fn note_overload(&self) {
        self.rejected_overload.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one undecodable request line.
    pub fn note_bad_request(&self) {
        self.bad_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one timed-out request detected outside the core (a job that
    /// expired while queued, or a reply the handler stopped waiting for).
    pub fn note_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// One consistent snapshot of every counter; `queue_depth` is passed
    /// in by the admission layer that owns the queue.
    pub fn stats(&self, queue_depth: usize) -> ServeStats {
        ServeStats {
            cache: self.cache_stats(),
            requests: self.requests.load(Ordering::Relaxed),
            fresh_searches: self.fresh_searches.load(Ordering::Relaxed),
            served_from_cache: self.served_from_cache.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            rejected_overload: self.rejected_overload.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            bad_requests: self.bad_requests.load(Ordering::Relaxed),
            queue_depth,
            in_flight: self.in_flight.load(Ordering::Relaxed),
            latency: *self.latency.lock().expect("serve latency poisoned"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_request() -> OptimizeRequest {
        let mut b = crate::graph::GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let _ = b.relu(x).unwrap();
        OptimizeRequest {
            graph: b.finish(),
            graph_name: "tiny".into(),
            method: Method::Greedy { max_steps: 4 },
            cost_noise: 0.0,
            noise_seed: 0,
            timeout_ms: None,
        }
    }

    #[test]
    fn fresh_then_cache_provenance() {
        let core = ServeCore::open(&ServeConfig { threads: 1, ..Default::default() }).unwrap();
        let req = tiny_request();
        let first = core.optimize(&req, None).unwrap();
        assert_eq!(first.provenance, Provenance::Fresh);
        let second = core.optimize(&req, None).unwrap();
        assert_eq!(second.provenance, Provenance::Cache);
        // The deterministic payload is identical across provenances.
        assert_eq!(
            first.payload("tiny").unwrap().to_string_compact(),
            second.payload("tiny").unwrap().to_string_compact()
        );
        let stats = core.stats(0);
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.fresh_searches, 1);
        assert_eq!(stats.served_from_cache, 1);
        assert_eq!(stats.latency.count, 2);
        assert_eq!(stats.in_flight, 0);
    }

    #[test]
    fn different_configs_do_not_alias() {
        let core = ServeCore::open(&ServeConfig { threads: 1, ..Default::default() }).unwrap();
        let mut req = tiny_request();
        assert_eq!(core.optimize(&req, None).unwrap().provenance, Provenance::Fresh);
        req.method = Method::Greedy { max_steps: 5 };
        // A different step budget is a different fingerprint: fresh again.
        assert_eq!(core.optimize(&req, None).unwrap().provenance, Provenance::Fresh);
    }
}
