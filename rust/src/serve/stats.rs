//! The daemon's observability surface: latency aggregates and the
//! [`ServeStats`] snapshot returned by the protocol's `stats` request.
//!
//! Cache counters reuse [`CacheStats`] (hit/miss/evict semantics are
//! identical to the CLI's `search cache:` line); the serve layer adds
//! request-level counters (provenance split, shed load, timeouts), the
//! live queue depth / in-flight gauge, and a constant-space latency
//! aggregate (count / mean / min / max — no histogram allocation on the
//! request path).

use crate::search::CacheStats;
use crate::util::json::Json;

/// Constant-space aggregate of served-request latencies (successful
/// servings only; sheds and timeouts are counted separately).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyAgg {
    /// Servings recorded.
    pub count: u64,
    /// Sum of latencies, seconds.
    pub total_s: f64,
    /// Fastest serving, seconds (0 until the first record).
    pub min_s: f64,
    /// Slowest serving, seconds.
    pub max_s: f64,
}

impl LatencyAgg {
    /// Fold one serving's wall-clock into the aggregate.
    pub fn record(&mut self, secs: f64) {
        if self.count == 0 || secs < self.min_s {
            self.min_s = secs;
        }
        if secs > self.max_s {
            self.max_s = secs;
        }
        self.count += 1;
        self.total_s += secs;
    }

    /// Mean serving latency in seconds (0 when nothing was recorded).
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_s / self.count as f64
        }
    }

    /// JSON object for the `stats` response.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("count", Json::Num(self.count as f64));
        j.set("mean_s", Json::Num(self.mean_s()));
        j.set("min_s", Json::Num(self.min_s));
        j.set("max_s", Json::Num(self.max_s));
        j
    }
}

/// One consistent snapshot of every daemon counter, as returned by the
/// `stats` request and printed on shutdown.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    /// Search-cache counters, cumulative across restarts when the daemon
    /// persists to a `--cache-dir` (prior-process totals are replayed
    /// from the snapshot header).
    pub cache: CacheStats,
    /// Optimise requests admitted (all provenances, including failures).
    pub requests: u64,
    /// Requests that ran a live search.
    pub fresh_searches: u64,
    /// Requests answered from the persistent cache.
    pub served_from_cache: u64,
    /// Requests that attached to another request's in-flight search.
    pub coalesced: u64,
    /// Requests shed with the `overloaded` error (queue full).
    pub rejected_overload: u64,
    /// Requests that hit their wall-clock budget.
    pub timeouts: u64,
    /// Lines that failed request decoding.
    pub bad_requests: u64,
    /// Jobs waiting in the admission queue right now.
    pub queue_depth: usize,
    /// Requests inside the serve core right now (leaders + followers).
    pub in_flight: usize,
    /// Latency aggregate over successful servings.
    pub latency: LatencyAgg,
}

impl ServeStats {
    /// JSON object for the `stats` response.
    pub fn to_json(&self) -> Json {
        let mut cache = Json::obj();
        cache.set("result_hits", Json::Num(self.cache.result_hits as f64));
        cache.set("result_misses", Json::Num(self.cache.result_misses as f64));
        cache.set("evictions", Json::Num(self.cache.evictions as f64));
        cache.set("result_entries", Json::Num(self.cache.result_entries as f64));
        cache.set("cost_entries", Json::Num(self.cache.cost_entries as f64));
        let mut j = Json::obj();
        j.set("cache", cache);
        j.set("requests", Json::Num(self.requests as f64));
        j.set("fresh_searches", Json::Num(self.fresh_searches as f64));
        j.set("served_from_cache", Json::Num(self.served_from_cache as f64));
        j.set("coalesced", Json::Num(self.coalesced as f64));
        j.set("rejected_overload", Json::Num(self.rejected_overload as f64));
        j.set("timeouts", Json::Num(self.timeouts as f64));
        j.set("bad_requests", Json::Num(self.bad_requests as f64));
        j.set("queue_depth", Json::Num(self.queue_depth as f64));
        j.set("in_flight", Json::Num(self.in_flight as f64));
        j.set("latency", self.latency.to_json());
        j
    }
}

/// One-line summary, printed by the daemon on shutdown and by
/// `rlflow request --stats`.
impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests ({} fresh, {} cached, {} coalesced; {} shed, {} timed out, {} bad); \
             queue {} / in-flight {}; mean latency {:.3}s; cache: {}",
            self.requests,
            self.fresh_searches,
            self.served_from_cache,
            self.coalesced,
            self.rejected_overload,
            self.timeouts,
            self.bad_requests,
            self.queue_depth,
            self.in_flight,
            self.latency.mean_s(),
            self.cache
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_agg_tracks_extremes_and_mean() {
        let mut a = LatencyAgg::default();
        assert_eq!(a.mean_s(), 0.0);
        a.record(0.2);
        a.record(0.1);
        a.record(0.6);
        assert_eq!(a.count, 3);
        assert!((a.min_s - 0.1).abs() < 1e-12);
        assert!((a.max_s - 0.6).abs() < 1e-12);
        assert!((a.mean_s() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn stats_json_has_every_counter() {
        let s = ServeStats {
            cache: CacheStats {
                result_hits: 2,
                result_misses: 1,
                evictions: 0,
                result_entries: 1,
                cost_entries: 5,
            },
            requests: 3,
            fresh_searches: 1,
            served_from_cache: 1,
            coalesced: 1,
            rejected_overload: 4,
            timeouts: 0,
            bad_requests: 2,
            queue_depth: 1,
            in_flight: 2,
            latency: LatencyAgg::default(),
        };
        let j = s.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("rejected_overload").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("cache").unwrap().get("result_hits").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("latency").unwrap().get("count").unwrap().as_usize().unwrap(), 0);
        // The Display line exists and mentions the shed count.
        assert!(s.to_string().contains("4 shed"));
    }
}
