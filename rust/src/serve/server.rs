//! The TCP shell of the daemon: listener, per-connection framing, the
//! admission queue and the worker pool. All optimisation semantics live
//! in [`ServeCore`] — this layer only moves lines and enforces the
//! admission contract:
//!
//! ```text
//! socket ── line framing ──> bounded queue ──> worker pool ──> ServeCore
//!   │          (8 MiB cap)     (overloaded      (N workers,     (coalesce,
//!   │                           when full)       deadline        cache,
//!   └── stats/ping answered inline              pre-check)       persist)
//! ```
//!
//! * `optimize` requests are queued; a full queue is answered with the
//!   typed `overloaded` error immediately — never a hang.
//! * `stats` and `ping` are answered inline on the connection thread, so
//!   observability keeps working while the queue is saturated.
//! * `shutdown` acknowledges, stops accepting, closes the queue (already
//!   -admitted jobs drain), joins the workers, snapshots the cache and
//!   returns from [`run`].
//! * Every request carries a wall-clock deadline (its `timeout_ms` or
//!   the server default). A job that expires while queued is answered
//!   `timeout` without running; a search that outlives its deadline keeps
//!   running (it still warms the cache) while the waiting request is
//!   answered `timeout`.
//! * A worker that panics mid-search is respawned in place
//!   ([`supervised_worker`]): the pool never shrinks, the panicked job's
//!   waiting connection resolves with `timeout` instead of hanging, and
//!   the daemon keeps serving (pinned in `tests/chaos.rs` via the
//!   `serve.worker` failpoint).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::protocol::{self, ErrorCode, OptimizeRequest, Request, Response};
use super::queue::{BoundedQueue, Popped, PushError};
use super::service::{ServeConfig, ServeCore, ServeError};

/// Simultaneous client connections admitted before shedding.
const MAX_CONNS: usize = 256;
/// Accept-loop poll interval while waiting for connections or shutdown.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Extra wait past a request's deadline for its worker to deliver the
/// timeout verdict before the connection handler gives up on the reply.
const REPLY_GRACE: Duration = Duration::from_millis(250);

/// Full daemon configuration: the TCP knobs plus the core's.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address, e.g. `127.0.0.1:7777` (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads consuming the queue. Each worker runs one search at
    /// a time (searches parallelise internally via `core.threads`).
    pub workers: usize,
    /// Admission-queue capacity; pushes beyond it are shed.
    pub queue_cap: usize,
    /// Default per-request wall-clock budget (overridable per request).
    pub default_timeout_ms: u64,
    /// Serve-core knobs (cache dir, bounds, search threads).
    pub core: ServeConfig,
}

impl ServerConfig {
    /// Defaults: 2 workers, queue of 64, 10-minute timeout, in-memory
    /// cache.
    pub fn new(addr: impl Into<String>) -> Self {
        Self {
            addr: addr.into(),
            workers: 2,
            queue_cap: 64,
            default_timeout_ms: 600_000,
            core: ServeConfig::default(),
        }
    }
}

struct Job {
    req: Box<OptimizeRequest>,
    deadline: Instant,
    reply: mpsc::Sender<Response>,
}

/// A running daemon: the bound address plus the join handle of its
/// accept loop. Tests bind port 0 and read the actual port from `addr`.
pub struct Handle {
    /// The address the listener actually bound.
    pub addr: SocketAddr,
    thread: JoinHandle<anyhow::Result<()>>,
}

impl Handle {
    /// Wait for the daemon to drain and exit (after a `shutdown`
    /// request), propagating its result.
    pub fn join(self) -> anyhow::Result<()> {
        match self.thread.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("serve accept loop panicked"),
        }
    }
}

/// Bind `cfg.addr` and run the daemon on background threads, returning
/// once the listener is live. [`run`] is the foreground wrapper the CLI
/// uses.
pub fn spawn(cfg: ServerConfig) -> anyhow::Result<Handle> {
    let core = Arc::new(ServeCore::open(&cfg.core)?);
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| anyhow::anyhow!("cannot bind {}: {e}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let thread = std::thread::Builder::new()
        .name("serve-accept".into())
        .spawn(move || accept_loop(listener, core, cfg))?;
    Ok(Handle { addr, thread })
}

/// Run the daemon in the foreground until a `shutdown` request drains
/// it. Prints the bound address on startup and the final stats line on
/// exit.
pub fn run(cfg: ServerConfig) -> anyhow::Result<()> {
    let replay_note = cfg.core.cache_dir.clone();
    let handle = spawn(cfg)?;
    println!("rlflow serve: listening on {}", handle.addr);
    if let Some(dir) = replay_note {
        println!("rlflow serve: persistent cache at {}", dir.display());
    }
    handle.join()
}

fn accept_loop(
    listener: TcpListener,
    core: Arc<ServeCore>,
    cfg: ServerConfig,
) -> anyhow::Result<()> {
    let queue: Arc<BoundedQueue<Job>> = Arc::new(BoundedQueue::new(cfg.queue_cap));
    let shutdown = Arc::new(AtomicBool::new(false));
    let conns = Arc::new(AtomicUsize::new(0));

    let mut workers = Vec::new();
    for i in 0..cfg.workers.max(1) {
        let q = Arc::clone(&queue);
        let c = Arc::clone(&core);
        workers.push(
            std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || supervised_worker(i, &q, &c))?,
        );
    }

    while !shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if conns.fetch_add(1, Ordering::AcqRel) >= MAX_CONNS {
                    conns.fetch_sub(1, Ordering::AcqRel);
                    core.note_overload();
                    let _ = shed_connection(stream);
                    continue;
                }
                let q = Arc::clone(&queue);
                let c = Arc::clone(&core);
                let sd = Arc::clone(&shutdown);
                let cn = Arc::clone(&conns);
                let timeout_ms = cfg.default_timeout_ms;
                let spawned = std::thread::Builder::new().name("serve-conn".into()).spawn(
                    move || {
                        handle_conn(stream, &q, &c, &sd, timeout_ms);
                        cn.fetch_sub(1, Ordering::AcqRel);
                    },
                );
                if spawned.is_err() {
                    conns.fetch_sub(1, Ordering::AcqRel);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                eprintln!("serve: accept failed: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }

    // Drain: no new admissions, finish queued work, persist, report.
    queue.close();
    for w in workers {
        let _ = w.join();
    }
    core.flush()?;
    println!("rlflow serve: drained; {}", core.stats(0));
    Ok(())
}

/// Keep one worker slot alive across panics: a panicking search (or an
/// armed `serve.worker` failpoint) kills this iteration of
/// [`worker_loop`], not the slot — the loop restarts it, so the pool
/// never shrinks. The panicked job's reply sender is dropped during
/// unwinding, which resolves its waiting connection with a `timeout`
/// (and any coalesced followers through the Flight drop-guard) rather
/// than a hang.
fn supervised_worker(i: usize, queue: &BoundedQueue<Job>, core: &ServeCore) {
    loop {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| worker_loop(queue, core)))
        {
            Ok(()) => break, // queue closed: clean drain
            Err(_) => eprintln!("serve: worker {i} panicked; respawning"),
        }
    }
}

fn worker_loop(queue: &BoundedQueue<Job>, core: &ServeCore) {
    // Expiry is decided atomically with the claim (under the queue
    // lock): a job can no longer expire between being popped and the
    // deadline check, so the verdict the worker acts on is the verdict
    // the job left the queue with.
    while let Some(popped) = queue.pop_where(|job| Instant::now() >= job.deadline) {
        let job = match popped {
            Popped::Expired(job) => {
                // Expired while queued: answered without running — the
                // client already gave up on it.
                let resp =
                    Response::error(ErrorCode::Timeout, "request timed out while queued");
                if job.reply.send(resp).is_ok() {
                    core.note_timeout();
                }
                continue;
            }
            Popped::Claimed(job) => job,
        };
        // Chaos hook: a panic here exercises the respawn path with a
        // claimed job in hand (outside the queue lock).
        crate::util::failpoint::fire("serve.worker");
        let name = job.req.graph_name.clone();
        let resp = match core.optimize(&job.req, Some(job.deadline)) {
            Ok(outcome) => match outcome.payload(&name) {
                Ok(payload) => Response::Result {
                    payload,
                    provenance: outcome.provenance,
                    elapsed_s: outcome.elapsed_s,
                },
                Err(e) => Response::error(ErrorCode::Internal, format!("payload encode: {e}")),
            },
            Err(ServeError::Timeout) => Response::error(ErrorCode::Timeout, "request timed out"),
            Err(ServeError::Failed(msg)) => Response::error(ErrorCode::Internal, msg),
        };
        let _ = job.reply.send(resp);
    }
}

/// Over the connection cap: answer the first line (best effort) with
/// `overloaded` and close.
fn shed_connection(stream: TcpStream) -> std::io::Result<()> {
    let mut stream = stream;
    stream.set_nonblocking(false)?;
    write_line(
        &mut stream,
        &Response::error(ErrorCode::Overloaded, "connection limit reached").encode(),
    )
}

fn write_line(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()
}

fn handle_conn(
    stream: TcpStream,
    queue: &BoundedQueue<Job>,
    core: &ServeCore,
    shutdown: &AtomicBool,
    default_timeout_ms: u64,
) {
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        // Per-line cap: reading one byte past the limit proves the line
        // is oversized without ever buffering unbounded input.
        let n = {
            let mut limited = (&mut reader).take(protocol::MAX_LINE_BYTES as u64 + 1);
            match limited.read_line(&mut line) {
                Ok(n) => n,
                Err(_) => {
                    // Undecodable bytes (or a half-closed socket): the
                    // stream cannot be re-framed, answer and close.
                    core.note_bad_request();
                    let _ = write_line(
                        &mut writer,
                        &Response::error(ErrorCode::BadRequest, "unreadable request line")
                            .encode(),
                    );
                    return;
                }
            }
        };
        if n == 0 {
            return; // clean EOF
        }
        if line.len() > protocol::MAX_LINE_BYTES {
            core.note_bad_request();
            let _ = write_line(
                &mut writer,
                &Response::error(
                    ErrorCode::BadRequest,
                    format!("request line exceeds {} bytes", protocol::MAX_LINE_BYTES),
                )
                .encode(),
            );
            return; // the rest of the stream is mid-line garbage
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let resp = match protocol::decode_request(trimmed) {
            Err(e) => {
                core.note_bad_request();
                Response::error(ErrorCode::BadRequest, e.to_string())
            }
            Ok(Request::Ping) => Response::Pong,
            Ok(Request::Stats) => Response::Stats(core.stats(queue.depth()).to_json()),
            Ok(Request::Shutdown) => {
                let resp = Response::Ok("draining".into());
                let _ = write_line(&mut writer, &resp.encode());
                shutdown.store(true, Ordering::Release);
                return;
            }
            Ok(Request::Optimize(req)) => {
                if shutdown.load(Ordering::Acquire) {
                    Response::error(ErrorCode::ShuttingDown, "daemon is draining")
                } else {
                    serve_optimize(req, queue, core, default_timeout_ms)
                }
            }
        };
        if write_line(&mut writer, &resp.encode()).is_err() {
            return;
        }
    }
}

fn serve_optimize(
    req: Box<OptimizeRequest>,
    queue: &BoundedQueue<Job>,
    core: &ServeCore,
    default_timeout_ms: u64,
) -> Response {
    let timeout = Duration::from_millis(req.timeout_ms.unwrap_or(default_timeout_ms));
    let deadline = Instant::now() + timeout;
    let (tx, rx) = mpsc::channel();
    match queue.push(Job { req, deadline, reply: tx }) {
        Err(PushError::Overloaded { depth }) => {
            core.note_overload();
            Response::error(ErrorCode::Overloaded, format!("queue full ({depth} queued)"))
        }
        Err(PushError::Closed) => Response::error(ErrorCode::ShuttingDown, "daemon is draining"),
        Ok(()) => match rx.recv_timeout(timeout + REPLY_GRACE) {
            Ok(resp) => resp,
            Err(_) => {
                // The worker never delivered (search overran its
                // deadline as leader, or the pool is saturated): the
                // search keeps running and warms the cache, but this
                // request is done waiting.
                core.note_timeout();
                Response::error(ErrorCode::Timeout, "request timed out")
            }
        },
    }
}
