//! Disk persistence for the daemon's [`SearchCache`](crate::search::SearchCache):
//! an append-only result log plus a periodically compacted snapshot.
//!
//! # Layout (`--cache-dir`)
//!
//! ```text
//! cache-dir/
//!   results.log     one compact-JSON entry per line, appended per fresh search
//!   snapshot.json   compacted full cache image, atomically replaced
//! ```
//!
//! Every entry carries the memo key — `(config fingerprint, canonical
//! root hash)`, both serialised as 16-hex-digit strings because a `u64`
//! does not survive a JSON `f64` — plus the final graph (ONNX-style model
//! JSON) and the memoised [`SearchLog`] fields. Startup replays the
//! snapshot first, then the log (log entries are newer and overwrite);
//! every [`Persister::snapshot_every`]-th append compacts the current
//! cache image into `snapshot.json` (written to a temp file, then
//! renamed) and truncates the log.
//!
//! # Crash behaviour
//!
//! * A torn final log line (crash mid-append) is skipped with a warning;
//!   every complete line still replays. If the log does not end in a
//!   newline, a repair newline is appended on open so the next append
//!   cannot merge into the torn tail and corrupt *two* entries.
//! * A crash between snapshot rename and log truncation replays log
//!   entries on top of the snapshot — re-storing an entry is idempotent.
//! * Each compaction keeps the previous snapshot as `snapshot.json.bak`.
//!   A corrupt (or missing-after-crash) `snapshot.json` is *not* fatal:
//!   startup warns, falls back to the `.bak` image plus a full log
//!   replay, and reports it via [`Replay::recovered_from_bak`]. Only
//!   entries newer than the `.bak` snapshot and absent from the log can
//!   be lost, and those were all served before the previous compaction.
//! * `elapsed_s` is deliberately *not* persisted (it is per-serving wall
//!   clock, not memoised state); replayed logs carry `elapsed_s = 0` and
//!   `from_cache = false`, exactly like
//!   [`SearchCache::store_hashed`](crate::search::SearchCache::store_hashed)
//!   re-stores them — so a warm-restarted daemon's `result` payloads are
//!   byte-identical to the pre-restart process (pinned in
//!   `tests/serve_core.rs`).
//!
//! The snapshot header additionally persists lifetime hit/miss/evict
//! counters so the `stats` surface is cumulative across restarts.

use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

use crate::graph::{onnx, Graph};
use crate::search::{CacheStats, SearchLog};
use crate::util::failpoint::{self, Action};
use crate::util::json::{parse, Json};

/// File name of the append-only result log inside the cache dir.
pub const LOG_FILE: &str = "results.log";
/// File name of the compacted snapshot inside the cache dir.
pub const SNAPSHOT_FILE: &str = "snapshot.json";
/// File name the previous snapshot is kept under across compactions.
pub const SNAPSHOT_BAK: &str = "snapshot.json.bak";
/// Format tag written into (and required of) every snapshot.
pub const SNAPSHOT_FORMAT: &str = "rlflow-servecache";
/// Current snapshot format version.
pub const SNAPSHOT_VERSION: usize = 1;

/// One persisted memo entry: the `(fingerprint, root hash)` key plus the
/// memoised result.
#[derive(Debug, Clone)]
pub struct CacheEntry {
    /// Search-config fingerprint ([`crate::search::memo::config_fingerprint`]).
    pub fp: u64,
    /// Canonical hash of the root graph the search started from.
    pub root: u64,
    /// The optimised graph the search produced.
    pub graph: Graph,
    /// The memoised search log (wall clock zeroed, see module docs).
    pub log: SearchLog,
}

fn hex(v: u64) -> String {
    format!("{v:016x}")
}

fn from_hex(s: &str) -> anyhow::Result<u64> {
    anyhow::ensure!(s.len() == 16, "expected 16 hex digits, got '{s}'");
    u64::from_str_radix(s, 16).map_err(|e| anyhow::anyhow!("bad hex '{s}': {e}"))
}

fn log_to_json(log: &SearchLog) -> Json {
    let mut j = Json::obj();
    j.set("initial_ms", Json::Num(log.initial_ms));
    j.set("final_ms", Json::Num(log.final_ms));
    j.set("graphs_explored", Json::Num(log.graphs_explored as f64));
    j.set("table_size", Json::Num(log.table_size as f64));
    j.set("memo_hits", Json::Num(log.memo_hits as f64));
    j.set("threads", Json::Num(log.threads as f64));
    j.set(
        "steps",
        Json::Arr(
            log.steps
                .iter()
                .map(|(rule, ms)| Json::Arr(vec![Json::Str(rule.clone()), Json::Num(*ms)]))
                .collect(),
        ),
    );
    j
}

fn log_from_json(j: &Json) -> anyhow::Result<SearchLog> {
    let steps = j
        .get("steps")?
        .as_arr()?
        .iter()
        .map(|s| {
            let pair = s.as_arr()?;
            anyhow::ensure!(pair.len() == 2, "step must be [rule, ms]");
            Ok((pair[0].as_str()?.to_string(), pair[1].as_f64()?))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(SearchLog {
        steps,
        initial_ms: j.get("initial_ms")?.as_f64()?,
        final_ms: j.get("final_ms")?.as_f64()?,
        elapsed_s: 0.0,
        graphs_explored: j.get("graphs_explored")?.as_usize()?,
        table_size: j.get("table_size")?.as_usize()?,
        memo_hits: j.get("memo_hits")?.as_usize()?,
        threads: j.get("threads")?.as_usize()?,
        from_cache: false,
    })
}

/// Serialise one entry as a (single-line when compact-encoded) JSON object.
pub fn entry_to_json(e: &CacheEntry) -> anyhow::Result<Json> {
    let mut j = Json::obj();
    j.set("fp", Json::Str(hex(e.fp)));
    j.set("root", Json::Str(hex(e.root)));
    j.set("graph", onnx::export(&e.graph, "cached")?);
    j.set("log", log_to_json(&e.log));
    Ok(j)
}

/// Parse one persisted entry (the graph passes full [`onnx::import`]
/// validation — a corrupted entry is an `Err`, never a bad cache hit).
pub fn entry_from_json(j: &Json) -> anyhow::Result<CacheEntry> {
    Ok(CacheEntry {
        fp: from_hex(j.get("fp")?.as_str()?)?,
        root: from_hex(j.get("root")?.as_str()?)?,
        graph: onnx::import(j.get("graph")?)?,
        log: log_from_json(j.get("log")?)?,
    })
}

/// What [`Persister::open`] recovered from disk.
pub struct Replay {
    /// Entries to re-store (snapshot first, then log — newest last).
    pub entries: Vec<CacheEntry>,
    /// Lifetime cache counters persisted by the previous process
    /// (`result_hits`, `result_misses`, `evictions`; sizes are zero).
    pub prior: CacheStats,
    /// Complete-but-unparseable log lines that were skipped.
    pub skipped_lines: usize,
    /// `snapshot.json` was corrupt or missing and the previous snapshot
    /// (`snapshot.json.bak`) was replayed instead.
    pub recovered_from_bak: bool,
}

/// Owner of a cache dir's log + snapshot files (see module docs). One
/// instance per daemon; callers serialise access behind a `Mutex`.
pub struct Persister {
    dir: PathBuf,
    log: File,
    appends_since_snapshot: usize,
    /// Appends between automatic compactions.
    pub snapshot_every: usize,
    /// A previous append failed and may have left an unterminated line;
    /// the next append re-terminates it first, so a committed entry
    /// never merges into the torn tail.
    tainted: bool,
}

/// Parse one snapshot file into `(entries, stats)`, validating format
/// tag, version, and every entry (the graphs pass full import checks).
fn read_snapshot(path: &Path) -> anyhow::Result<(Vec<CacheEntry>, CacheStats)> {
    let text = std::fs::read_to_string(path)?;
    let j = parse(&text).map_err(|e| anyhow::anyhow!("corrupt snapshot {}: {e}", path.display()))?;
    let format = j.get("format")?.as_str()?;
    anyhow::ensure!(
        format == SNAPSHOT_FORMAT,
        "{} is not a serve cache snapshot (format '{format}')",
        path.display()
    );
    let version = j.get("version")?.as_usize()?;
    anyhow::ensure!(
        version == SNAPSHOT_VERSION,
        "snapshot version {version} unsupported (expected {SNAPSHOT_VERSION})"
    );
    let mut prior = CacheStats::default();
    let st = j.get("stats")?;
    prior.result_hits = st.get("result_hits")?.as_usize()? as u64;
    prior.result_misses = st.get("result_misses")?.as_usize()? as u64;
    prior.evictions = st.get("evictions")?.as_usize()? as u64;
    let mut entries = Vec::new();
    for ej in j.get("entries")?.as_arr()? {
        entries.push(
            entry_from_json(ej)
                .map_err(|e| anyhow::anyhow!("corrupt snapshot entry in {}: {e}", path.display()))?,
        );
    }
    Ok((entries, prior))
}

impl Persister {
    /// Open (creating if needed) a cache dir, replaying whatever previous
    /// processes persisted. A missing dir or empty files yield an empty
    /// [`Replay`]. A corrupt or missing `snapshot.json` falls back to the
    /// previous snapshot (`snapshot.json.bak`, kept across compactions)
    /// with a warning — startup only degrades, never dies — and corrupt
    /// trailing *log* lines are skipped and counted (torn final append).
    pub fn open(dir: &Path, snapshot_every: usize) -> anyhow::Result<(Persister, Replay)> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create cache dir {}: {e}", dir.display()))?;
        let mut entries = Vec::new();
        let mut prior = CacheStats::default();
        let mut recovered_from_bak = false;

        let snap_path = dir.join(SNAPSHOT_FILE);
        let bak_path = dir.join(SNAPSHOT_BAK);
        let primary = if snap_path.exists() {
            match read_snapshot(&snap_path) {
                Ok(got) => Some(got),
                Err(e) => {
                    eprintln!("serve: {e}; falling back to {SNAPSHOT_BAK}");
                    None
                }
            }
        } else {
            None
        };
        match primary {
            Some((es, st)) => {
                entries = es;
                prior = st;
            }
            None if bak_path.exists() => {
                let (es, st) = read_snapshot(&bak_path).map_err(|e| {
                    anyhow::anyhow!("both snapshot and backup are unreadable: {e}")
                })?;
                recovered_from_bak = true;
                eprintln!(
                    "serve: recovered {} entries from {SNAPSHOT_BAK} + log replay",
                    es.len()
                );
                entries = es;
                prior = st;
            }
            None => {}
        }

        let log_path = dir.join(LOG_FILE);
        // Repair a torn tail before appending anything new: without the
        // newline, the next append would merge into the torn line and
        // corrupt a *committed* entry too.
        if let Ok(bytes) = std::fs::read(&log_path) {
            if !bytes.is_empty() && bytes.last() != Some(&b'\n') {
                eprintln!("serve: cache log has a torn tail; appending repair newline");
                OpenOptions::new().append(true).open(&log_path)?.write_all(b"\n")?;
            }
        }
        let mut skipped_lines = 0usize;
        if log_path.exists() {
            let reader = BufReader::new(File::open(&log_path)?);
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                match parse(&line).and_then(|j| entry_from_json(&j)) {
                    Ok(e) => entries.push(e),
                    Err(err) => {
                        skipped_lines += 1;
                        eprintln!("serve: skipping corrupt cache log line: {err}");
                    }
                }
            }
        }

        let log = OpenOptions::new().append(true).create(true).open(&log_path)?;
        Ok((
            Persister {
                dir: dir.to_path_buf(),
                log,
                appends_since_snapshot: 0,
                snapshot_every: snapshot_every.max(1),
                tainted: false,
            },
            Replay { entries, prior, skipped_lines, recovered_from_bak },
        ))
    }

    /// Append one fresh result to the log (flushed before returning, so a
    /// crash after a response was sent never loses its entry). Returns
    /// `true` when a compaction is due — the caller then invokes
    /// [`Persister::snapshot`] with the full current cache image.
    /// Failpoint sites: `serve.log.append` (where `short(n)` tears the
    /// line after `n` bytes) and `serve.log.flush` (arm `exit` there to
    /// simulate a kill before buffered bytes reach the file).
    pub fn append(&mut self, e: &CacheEntry) -> anyhow::Result<bool> {
        let line = entry_to_json(e)?.to_string_compact();
        if self.tainted {
            // A previous append failed mid-line and the daemon carried
            // on: terminate the torn tail so this entry gets its own
            // line (the garbage line is skipped, not merged, on replay).
            self.log.write_all(b"\n")?;
            self.log.flush()?;
            self.tainted = false;
        }
        match failpoint::hit("serve.log.append") {
            Action::Short(n) => {
                let n = n.min(line.len());
                self.log.write_all(&line.as_bytes()[..n])?;
                self.log.flush()?;
                self.tainted = true;
                anyhow::bail!(
                    "failpoint serve.log.append: short write ({n} of {} bytes)",
                    line.len()
                );
            }
            Action::Err => anyhow::bail!("failpoint serve.log.append: injected fault"),
            Action::Panic => panic!("failpoint serve.log.append: injected panic"),
            Action::Exit => {
                eprintln!("failpoint serve.log.append: simulated kill");
                std::process::exit(failpoint::EXIT_CODE);
            }
            Action::Delay(ms) => std::thread::sleep(std::time::Duration::from_millis(ms)),
            Action::Proceed => {}
        }
        if let Err(e) =
            self.log.write_all(line.as_bytes()).and_then(|()| self.log.write_all(b"\n"))
        {
            self.tainted = true;
            return Err(e.into());
        }
        failpoint::check("serve.log.flush")?;
        self.log.flush()?;
        self.appends_since_snapshot += 1;
        Ok(self.appends_since_snapshot >= self.snapshot_every)
    }

    /// Write a compacted snapshot of `entries` (plus lifetime `stats`
    /// counters) atomically — temp file, then rename — and truncate the
    /// log it subsumes. `entries` must be the cache's full current image
    /// in deterministic order
    /// ([`SearchCache::snapshot_results`](crate::search::SearchCache::snapshot_results)):
    /// a fixed cache state always snapshots to identical bytes.
    pub fn snapshot(&mut self, entries: &[CacheEntry], stats: &CacheStats) -> anyhow::Result<()> {
        let mut st = Json::obj();
        st.set("result_hits", Json::Num(stats.result_hits as f64));
        st.set("result_misses", Json::Num(stats.result_misses as f64));
        st.set("evictions", Json::Num(stats.evictions as f64));
        let mut j = Json::obj();
        j.set("format", Json::Str(SNAPSHOT_FORMAT.into()));
        j.set("version", Json::Num(SNAPSHOT_VERSION as f64));
        j.set("stats", st);
        j.set(
            "entries",
            Json::Arr(entries.iter().map(entry_to_json).collect::<anyhow::Result<_>>()?),
        );

        let tmp = self.dir.join("snapshot.json.tmp");
        let final_path = self.dir.join(SNAPSHOT_FILE);
        failpoint::check("serve.snapshot.write")?;
        {
            let mut f = File::create(&tmp)?;
            f.write_all(j.to_string_compact().as_bytes())?;
            f.write_all(b"\n")?;
            f.flush()?;
            f.sync_all()?;
        }
        failpoint::check("serve.snapshot.rename")?;
        // Keep the outgoing snapshot as the fallback image: if the new
        // one is later torn or unreadable, open() recovers from the .bak
        // plus the (then still untruncated) log.
        if final_path.exists() {
            std::fs::rename(&final_path, self.dir.join(SNAPSHOT_BAK))?;
        }
        std::fs::rename(&tmp, &final_path)?;
        // The snapshot subsumes every logged entry: start the log over.
        self.log = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(self.dir.join(LOG_FILE))?;
        self.appends_since_snapshot = 0;
        self.tainted = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rlflow-persist-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample_entry(fp: u64) -> CacheEntry {
        let mut b = crate::graph::GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let _ = b.relu(x).unwrap();
        let g = b.finish();
        let root = crate::graph::canonical_hash(&g);
        CacheEntry {
            fp,
            root,
            graph: g,
            log: SearchLog {
                steps: vec![("fuse".into(), 1.25)],
                initial_ms: 2.0,
                final_ms: 1.25,
                elapsed_s: 0.0,
                graphs_explored: 7,
                table_size: 9,
                memo_hits: 3,
                threads: 4,
                from_cache: false,
            },
        }
    }

    #[test]
    fn entry_json_round_trips_keys_exactly() {
        let e = sample_entry(0xDEAD_BEEF_0000_0001);
        let j = entry_to_json(&e).unwrap();
        let back = entry_from_json(&j).unwrap();
        assert_eq!(back.fp, e.fp, "u64 keys must survive the hex encoding");
        assert_eq!(back.root, e.root);
        assert_eq!(
            crate::graph::canonical_hash(&back.graph),
            crate::graph::canonical_hash(&e.graph)
        );
        assert_eq!(back.log.steps, e.log.steps);
        assert_eq!(back.log.final_ms.to_bits(), e.log.final_ms.to_bits());
        // Re-encoding is byte-stable (deterministic persistence).
        assert_eq!(
            entry_to_json(&back).unwrap().to_string_compact(),
            j.to_string_compact()
        );
    }

    #[test]
    fn log_and_snapshot_replay() {
        let dir = tmpdir("replay");
        {
            let (mut p, replay) = Persister::open(&dir, 100).unwrap();
            assert!(replay.entries.is_empty());
            assert_eq!(replay.prior, CacheStats::default());
            assert!(!p.append(&sample_entry(1)).unwrap());
            assert!(!p.append(&sample_entry(2)).unwrap());
        }
        // Reopen: both logged entries replay, in append order.
        {
            let (mut p, replay) = Persister::open(&dir, 100).unwrap();
            assert_eq!(replay.entries.len(), 2);
            assert_eq!(replay.entries[0].fp, 1);
            assert_eq!(replay.entries[1].fp, 2);
            // Compact: snapshot carries the image + counters, log restarts.
            let stats = CacheStats {
                result_hits: 5,
                result_misses: 3,
                evictions: 1,
                result_entries: 2,
                cost_entries: 0,
            };
            p.snapshot(&replay.entries, &stats).unwrap();
            assert!(!p.append(&sample_entry(3)).unwrap());
        }
        // Reopen again: snapshot entries first, then the fresh log entry;
        // prior counters recovered.
        let (_p, replay) = Persister::open(&dir, 100).unwrap();
        assert_eq!(replay.entries.len(), 3);
        assert_eq!(replay.entries[2].fp, 3);
        assert_eq!(replay.prior.result_hits, 5);
        assert_eq!(replay.prior.result_misses, 3);
        assert_eq!(replay.prior.evictions, 1);
        assert_eq!(replay.skipped_lines, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_log_line_is_skipped_not_fatal() {
        let dir = tmpdir("torn");
        {
            let (mut p, _) = Persister::open(&dir, 100).unwrap();
            let _ = p.append(&sample_entry(7)).unwrap();
        }
        // Simulate a crash mid-append: garbage trailing line.
        {
            let mut f = OpenOptions::new().append(true).open(dir.join(LOG_FILE)).unwrap();
            f.write_all(b"{\"fp\":\"00000000000000").unwrap();
        }
        let (_p, replay) = Persister::open(&dir, 100).unwrap();
        assert_eq!(replay.entries.len(), 1, "complete lines must still replay");
        assert_eq!(replay.skipped_lines, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_recovers_from_bak() {
        let dir = tmpdir("bak");
        {
            let (mut p, _) = Persister::open(&dir, 100).unwrap();
            let _ = p.append(&sample_entry(1)).unwrap();
            p.snapshot(&[sample_entry(1)], &CacheStats::default()).unwrap();
            let _ = p.append(&sample_entry(2)).unwrap();
            p.snapshot(&[sample_entry(1), sample_entry(2)], &CacheStats::default()).unwrap();
            let _ = p.append(&sample_entry(3)).unwrap();
        }
        assert!(dir.join(SNAPSHOT_BAK).exists(), "compaction keeps the previous snapshot");
        // Byte-mutate the live snapshot at several positions: startup
        // must warn and recover from the .bak + log, never die.
        let clean = std::fs::read(dir.join(SNAPSHOT_FILE)).unwrap();
        for pos in [0, clean.len() / 2, clean.len() - 2] {
            let mut bad = clean.clone();
            bad[pos] ^= 0x15;
            std::fs::write(dir.join(SNAPSHOT_FILE), &bad).unwrap();
            let (_p, replay) = Persister::open(&dir, 100).unwrap();
            assert!(replay.recovered_from_bak, "mutation at byte {pos}");
            // .bak holds entry 1; the untruncated log holds entry 3.
            let fps: Vec<u64> = replay.entries.iter().map(|e| e.fp).collect();
            assert!(fps.contains(&1) && fps.contains(&3), "got {fps:?}");
        }
        // With the snapshot intact nothing falls back.
        std::fs::write(dir.join(SNAPSHOT_FILE), &clean).unwrap();
        let (_p, replay) = Persister::open(&dir, 100).unwrap();
        assert!(!replay.recovered_from_bak);
        assert_eq!(replay.entries.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_without_bak_degrades_to_log_replay() {
        let dir = tmpdir("nobak");
        {
            let (mut p, _) = Persister::open(&dir, 100).unwrap();
            let _ = p.append(&sample_entry(1)).unwrap();
            p.snapshot(&[sample_entry(1)], &CacheStats::default()).unwrap();
            let _ = p.append(&sample_entry(2)).unwrap();
        }
        // First compaction has no predecessor, so no .bak exists yet:
        // corrupting the only snapshot degrades to a log-only replay
        // with a warning — startup still must not die.
        assert!(!dir.join(SNAPSHOT_BAK).exists());
        std::fs::write(dir.join(SNAPSHOT_FILE), b"{definitely not json").unwrap();
        let (_p, replay) = Persister::open(&dir, 100).unwrap();
        assert!(!replay.recovered_from_bak);
        let fps: Vec<u64> = replay.entries.iter().map(|e| e.fp).collect();
        assert_eq!(fps, vec![2], "post-snapshot log entries survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_repaired_before_new_appends() {
        let dir = tmpdir("tail");
        {
            let (mut p, _) = Persister::open(&dir, 100).unwrap();
            let _ = p.append(&sample_entry(1)).unwrap();
        }
        // Crash mid-append: no trailing newline.
        {
            let mut f = OpenOptions::new().append(true).open(dir.join(LOG_FILE)).unwrap();
            f.write_all(b"{\"fp\":\"00000000").unwrap();
        }
        {
            let (mut p, replay) = Persister::open(&dir, 100).unwrap();
            assert_eq!(replay.entries.len(), 1);
            assert_eq!(replay.skipped_lines, 1);
            let _ = p.append(&sample_entry(2)).unwrap();
        }
        // Without the repair newline, entry 2 would merge into the torn
        // tail and BOTH would be lost.
        let (_p, replay) = Persister::open(&dir, 100).unwrap();
        let fps: Vec<u64> = replay.entries.iter().map(|e| e.fp).collect();
        assert_eq!(fps, vec![1, 2]);
        assert_eq!(replay.skipped_lines, 1, "the torn line itself stays skipped");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_cadence_requests_snapshot() {
        let dir = tmpdir("cadence");
        let (mut p, _) = Persister::open(&dir, 2).unwrap();
        assert!(!p.append(&sample_entry(1)).unwrap());
        assert!(p.append(&sample_entry(2)).unwrap(), "every 2nd append compacts");
        p.snapshot(&[sample_entry(1), sample_entry(2)], &CacheStats::default()).unwrap();
        // Cadence resets after a snapshot.
        assert!(!p.append(&sample_entry(3)).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
