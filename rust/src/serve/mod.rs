//! `rlflow serve` — optimisation-as-a-service on the persistent
//! [`SearchCache`](crate::search::SearchCache).
//!
//! A long-running, dependency-free daemon (`std::net` + threads, no
//! async runtime) that turns search results into the cacheable commodity
//! the ROADMAP's production north-star needs: one warm cache serving
//! many callers, surviving restarts, with explicit load shedding instead
//! of collapse under overload.
//!
//! ```text
//!          ┌────────────────────────── rlflow serve ───────────────────────────┐
//! client ──┤ TCP listener → line framing → bounded queue → worker pool         │
//!  (NDJSON)│                                  │                │               │
//!          │             stats/ping inline ◄──┘        ServeCore.optimize      │
//!          │                                    (coalescing → SearchCache      │
//!          │                                       → append log / snapshot)    │
//!          └────────────────────────────────────────────────────────────────────┘
//! ```
//!
//! * [`protocol`] — the newline-delimited JSON wire format and its
//!   determinism contract (the `result` payload is byte-identical for a
//!   given request, whatever its provenance).
//! * [`service`] — [`ServeCore`]: coalescing, provenance, counters;
//!   fully testable without sockets.
//! * [`persist`] — append-only result log + compacted snapshots under
//!   `--cache-dir`; replay makes warm restarts bit-identical.
//! * [`queue`] — the bounded admission queue (typed `overloaded`, never
//!   a hang).
//! * [`server`] — listener, connection handling, worker pool, graceful
//!   drain.
//! * [`client`] — the one-shot client behind `rlflow request`, with a
//!   seeded-backoff retry policy for transient (`overloaded`/`timeout`)
//!   failures.

pub mod client;
pub mod persist;
pub mod protocol;
pub mod queue;
pub mod server;
pub mod service;
pub mod stats;

pub use client::{roundtrip, roundtrip_retry, RetryCfg, DEFAULT_READ_TIMEOUT};
pub use protocol::{
    decode_request, encode_control, encode_optimize, result_payload, ErrorCode, Method,
    OptimizeRequest, Provenance, Request, Response,
};
pub use queue::{BoundedQueue, Popped, PushError};
pub use server::{run, spawn, Handle, ServerConfig};
pub use service::{Outcome, ServeConfig, ServeCore, ServeError, Served};
pub use stats::{LatencyAgg, ServeStats};
