//! Bounded MPMC work queue — the admission-control half of the daemon.
//!
//! Connection handlers push parsed optimise jobs; the worker pool pops
//! them. The queue is deliberately *non-blocking on push*: a full queue
//! returns the typed [`PushError::Overloaded`] immediately, which the
//! server maps to the protocol's `overloaded` error — load is shed with
//! an explicit response, never by letting a client hang on an unbounded
//! backlog. `pop` blocks (that is the worker's idle state) and drains
//! remaining jobs after [`BoundedQueue::close`] so graceful shutdown
//! finishes accepted work before exiting.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// The queue is at capacity; the caller should shed the request.
    Overloaded {
        /// Queue depth observed at rejection time.
        depth: usize,
    },
    /// The queue was closed for shutdown; no new work is admitted.
    Closed,
}

/// Verdict of a [`BoundedQueue::pop_where`] claim, decided under the
/// queue lock atomically with removal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Popped<T> {
    /// Claimed before expiry: the worker must run it.
    Claimed(T),
    /// Already expired when claimed: the worker must answer timeout
    /// without running it. The item is handed back for the reply path.
    Expired(T),
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A capacity-bounded multi-producer/multi-consumer queue with explicit
/// load shedding (see the module docs).
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    /// A queue admitting at most `cap` pending items (minimum 1).
    pub fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Admit one item, or refuse immediately: [`PushError::Overloaded`]
    /// at capacity, [`PushError::Closed`] after [`BoundedQueue::close`].
    pub fn push(&self, item: T) -> Result<(), PushError> {
        let mut s = self.state.lock().expect("serve queue poisoned");
        if s.closed {
            return Err(PushError::Closed);
        }
        if s.items.len() >= self.cap {
            return Err(PushError::Overloaded { depth: s.items.len() });
        }
        s.items.push_back(item);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until an item is available and take it. Returns `None` only
    /// once the queue is closed *and* fully drained — accepted work is
    /// always completed before workers exit.
    pub fn pop(&self) -> Option<T> {
        match self.pop_where(|_| false) {
            Some(Popped::Claimed(item)) => Some(item),
            Some(Popped::Expired(_)) => unreachable!("predicate is constant false"),
            None => None,
        }
    }

    /// [`BoundedQueue::pop`] with expiry made atomic with the claim:
    /// `expired` is evaluated on the item *while the queue lock is held*,
    /// so the verdict — [`Popped::Claimed`] (run it) vs
    /// [`Popped::Expired`] (answer timeout, don't run) — is decided in
    /// the same critical section that removes the item. A separate
    /// pop-then-check sequence leaves a window where the deadline passes
    /// after the check but before the work starts; with `pop_where` no
    /// such window exists — whichever verdict the worker observes is the
    /// one the item left the queue with.
    pub fn pop_where(&self, expired: impl Fn(&T) -> bool) -> Option<Popped<T>> {
        let mut s = self.state.lock().expect("serve queue poisoned");
        loop {
            if let Some(item) = s.items.pop_front() {
                return Some(if expired(&item) {
                    Popped::Expired(item)
                } else {
                    Popped::Claimed(item)
                });
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).expect("serve queue poisoned");
        }
    }

    /// Stop admitting work and wake every blocked worker. Already-queued
    /// items still drain through [`BoundedQueue::pop`].
    pub fn close(&self) {
        let mut s = self.state.lock().expect("serve queue poisoned");
        s.closed = true;
        self.ready.notify_all();
    }

    /// Number of items currently queued (the `stats` surface's
    /// `queue_depth`).
    pub fn depth(&self) -> usize {
        self.state.lock().expect("serve queue poisoned").items.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_and_depth() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn overflow_is_typed_not_blocking() {
        let q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        // The third push returns instantly with the typed error.
        assert_eq!(q.push(3), Err(PushError::Overloaded { depth: 2 }));
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
    }

    #[test]
    fn pop_where_classifies_under_the_lock() {
        use std::time::{Duration, Instant};
        let q = BoundedQueue::new(4);
        let now = Instant::now();
        // Item 1's deadline already passed when it is claimed; item 2's
        // has not. Classification rides the FIFO order.
        q.push((1u32, now - Duration::from_millis(1))).unwrap();
        q.push((2u32, now + Duration::from_secs(60))).unwrap();
        match q.pop_where(|&(_, d)| Instant::now() >= d) {
            Some(Popped::Expired((1, _))) => {}
            other => panic!("expected Expired(1), got {other:?}"),
        }
        match q.pop_where(|&(_, d)| Instant::now() >= d) {
            Some(Popped::Claimed((2, _))) => {}
            other => panic!("expected Claimed(2), got {other:?}"),
        }
    }

    #[test]
    fn pop_where_drains_after_close_and_preserves_fifo() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.pop_where(|&x| x == 1), Some(Popped::Expired(1)));
        assert_eq!(q.pop_where(|&x| x == 1), Some(Popped::Claimed(2)));
        assert_eq!(q.pop_where(|_| false), None, "closed and drained");
    }

    #[test]
    fn close_drains_then_releases_workers() {
        let q = Arc::new(BoundedQueue::new(8));
        q.push(10).unwrap();
        q.close();
        assert_eq!(q.push(11), Err(PushError::Closed));
        // Queued work drains; only then do poppers see the end.
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), None);
        // A worker blocked in pop() when close() fires is woken.
        let q2 = Arc::new(BoundedQueue::<u32>::new(1));
        let qw = Arc::clone(&q2);
        let h = std::thread::spawn(move || qw.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q2.close();
        assert_eq!(h.join().unwrap(), None);
    }
}
