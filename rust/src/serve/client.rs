//! Minimal blocking client for the serve protocol — the engine behind
//! `rlflow request`, the CI smoke job and the end-to-end tests.
//!
//! One connection per call: connect, write one request line, read one
//! response line, decode. The daemon supports pipelined connections, but
//! the CLI's needs are strictly request/response and a fresh connection
//! keeps every invocation independent.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::protocol::{Response, MAX_LINE_BYTES};

/// Default client-side read timeout (generous: a cold TASO search on the
/// largest zoo graph finishes well inside this).
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(900);

/// Send one request line to `addr` and decode the single response line.
/// `read_timeout` bounds the wait for the daemon's answer (the daemon
/// enforces its own per-request budget too — see the protocol's
/// `timeout` error).
pub fn roundtrip(addr: &str, line: &str, read_timeout: Duration) -> anyhow::Result<Response> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("cannot connect to rlflow serve at {addr}: {e}"))?;
    stream.set_read_timeout(Some(read_timeout))?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;

    let mut reader = BufReader::new(stream).take(MAX_LINE_BYTES as u64 + 1);
    let mut resp = String::new();
    let n = reader
        .read_line(&mut resp)
        .map_err(|e| anyhow::anyhow!("reading response from {addr}: {e}"))?;
    anyhow::ensure!(n > 0, "server at {addr} closed the connection without responding");
    anyhow::ensure!(
        resp.len() <= MAX_LINE_BYTES,
        "response line exceeds {} bytes",
        MAX_LINE_BYTES
    );
    Response::decode(resp.trim())
}
