//! Minimal blocking client for the serve protocol — the engine behind
//! `rlflow request`, the CI smoke job and the end-to-end tests.
//!
//! One connection per call: connect, write one request line, read one
//! response line, decode. The daemon supports pipelined connections, but
//! the CLI's needs are strictly request/response and a fresh connection
//! keeps every invocation independent.
//!
//! [`roundtrip_retry`] adds a transient-failure policy on top: typed
//! `overloaded`/`timeout` responses and transport errors (refused
//! connection, dropped socket) are retryable; everything else —
//! `bad_request` above all — is final and returned as-is. Backoff is
//! exponential with seeded jitter, so a retry schedule is replayable.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::protocol::{ErrorCode, Response, MAX_LINE_BYTES};
use crate::util::Rng;

/// Default client-side read timeout (generous: a cold TASO search on the
/// largest zoo graph finishes well inside this).
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(900);

/// Send one request line to `addr` and decode the single response line.
/// `read_timeout` bounds the wait for the daemon's answer (the daemon
/// enforces its own per-request budget too — see the protocol's
/// `timeout` error).
pub fn roundtrip(addr: &str, line: &str, read_timeout: Duration) -> anyhow::Result<Response> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("cannot connect to rlflow serve at {addr}: {e}"))?;
    stream.set_read_timeout(Some(read_timeout))?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;

    let mut reader = BufReader::new(stream).take(MAX_LINE_BYTES as u64 + 1);
    let mut resp = String::new();
    let n = reader
        .read_line(&mut resp)
        .map_err(|e| anyhow::anyhow!("reading response from {addr}: {e}"))?;
    anyhow::ensure!(n > 0, "server at {addr} closed the connection without responding");
    anyhow::ensure!(
        resp.len() <= MAX_LINE_BYTES,
        "response line exceeds {} bytes",
        MAX_LINE_BYTES
    );
    Response::decode(resp.trim())
}

/// Retry policy for [`roundtrip_retry`].
#[derive(Debug, Clone)]
pub struct RetryCfg {
    /// Extra attempts after the first (0 = exactly one attempt).
    pub retries: usize,
    /// Total backoff-sleep budget across all retries, in milliseconds;
    /// retrying stops once the budget is spent even if attempts remain.
    pub budget_ms: u64,
    /// Seed for the jitter stream (replayable backoff schedules).
    pub seed: u64,
}

impl Default for RetryCfg {
    fn default() -> Self {
        Self { retries: 0, budget_ms: 10_000, seed: 0 }
    }
}

/// Whether a decoded response is worth retrying: `overloaded` (shed by
/// the admission queue or connection cap) and `timeout` are transient;
/// every other response — results, `bad_request`, `shutting_down` — is
/// final.
pub fn is_retryable(resp: &Response) -> bool {
    matches!(
        resp,
        Response::Error { code: ErrorCode::Overloaded | ErrorCode::Timeout, .. }
    )
}

/// [`roundtrip`] with retries: transient failures (see [`is_retryable`];
/// transport-level errors count too) back off exponentially —
/// `50ms * 2^attempt` plus seeded jitter of up to half that, capped by
/// the remaining `budget_ms` — and try again. Returns the final response
/// plus the number of attempts made (at least 1), or the last transport
/// error once attempts or budget run out.
pub fn roundtrip_retry(
    addr: &str,
    line: &str,
    read_timeout: Duration,
    retry: &RetryCfg,
) -> anyhow::Result<(Response, usize)> {
    let mut rng = Rng::new(retry.seed);
    let started = Instant::now();
    let budget = Duration::from_millis(retry.budget_ms);
    for attempt in 1..=retry.retries + 1 {
        let outcome = roundtrip(addr, line, read_timeout);
        let transient = match &outcome {
            Ok(resp) => is_retryable(resp),
            Err(_) => true,
        };
        if !transient || attempt > retry.retries {
            return outcome.map(|r| (r, attempt));
        }
        let base = 50u64.saturating_mul(1 << (attempt - 1).min(10));
        let jitter = rng.next_u64() % (base / 2 + 1);
        let sleep = Duration::from_millis(base + jitter);
        if started.elapsed() + sleep > budget {
            // Budget exhausted: surface the last outcome rather than
            // sleeping past the caller's deadline.
            return match outcome {
                Ok(r) => Ok((r, attempt)),
                Err(e) => Err(anyhow::anyhow!(
                    "retry budget ({} ms) exhausted after {attempt} attempts: {e}",
                    retry.budget_ms
                )),
            };
        }
        std::thread::sleep(sleep);
    }
    unreachable!("loop always returns by the last attempt");
}
