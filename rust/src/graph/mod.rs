//! Computation-graph intermediate representation (§2.1, §3.2).
//!
//! A [`Graph`] is a DAG of tensor operations with multi-output nodes
//! (needed for `Split`) and stable [`NodeId`]s — substitution application
//! tombstones removed nodes rather than renumbering, so location indices
//! observed by the RL agent stay meaningful within a step.

pub mod builder;
pub mod graph;
pub mod hash;
pub mod onnx;
pub mod op;
pub mod shapes;
pub mod tensor;

pub use builder::GraphBuilder;
pub use graph::{Graph, Node, NodeId, PortRef};
pub use hash::canonical_hash;
pub use op::{Activation, OpKind, PadMode};
pub use tensor::{DType, TensorDesc};
