//! Shape inference: given an operator and its input descriptors, compute the
//! output descriptors (or a descriptive error). This is the single source of
//! truth — the builder, the substitution applier and the ONNX importer all
//! route through [`infer`].

use super::op::{OpKind, PadMode};
use super::tensor::{DType, TensorDesc};

pub fn conv_out_dim(input: usize, k: usize, stride: usize, pad: PadMode) -> Option<usize> {
    match pad {
        PadMode::Same => Some(input.div_ceil(stride)),
        PadMode::Valid => {
            if input < k {
                None
            } else {
                Some((input - k) / stride + 1)
            }
        }
    }
}

pub fn infer(op: &OpKind, inputs: &[&TensorDesc]) -> anyhow::Result<Vec<TensorDesc>> {
    use OpKind::*;
    if let Some(n) = op.arity() {
        anyhow::ensure!(inputs.len() == n, "{}: expected {} inputs, got {}", op.name(), n, inputs.len());
    } else {
        anyhow::ensure!(!inputs.is_empty(), "{}: needs at least one input", op.name());
    }
    let out = match op {
        Input | Weight => {
            anyhow::bail!("{}: source ops carry their own descriptor", op.name())
        }
        ConvBias { stride, pad, .. } => {
            let x = inputs[0];
            let w = inputs[1];
            anyhow::ensure!(x.rank() == 4 && w.rank() == 4, "conv_bias: need NCHW x OIHW");
            anyhow::ensure!(x.shape[1] == w.shape[1], "conv_bias: channel mismatch");
            anyhow::ensure!(inputs[2].shape == vec![w.shape[0]], "conv_bias: bias must be [C_out]");
            let oh = conv_out_dim(x.shape[2], w.shape[2], *stride, *pad)
                .ok_or_else(|| anyhow::anyhow!("conv_bias: kernel too large"))?;
            let ow = conv_out_dim(x.shape[3], w.shape[3], *stride, *pad)
                .ok_or_else(|| anyhow::anyhow!("conv_bias: kernel too large"))?;
            vec![TensorDesc::f32(&[x.shape[0], w.shape[0], oh, ow])]
        }
        Conv2d { stride, pad, .. } => {
            let x = inputs[0];
            let w = inputs[1];
            anyhow::ensure!(x.rank() == 4 && w.rank() == 4, "conv2d: need NCHW x OIHW");
            let (n, c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
            let (co, ci, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
            anyhow::ensure!(c == ci, "conv2d: channels {} != kernel in-channels {}", c, ci);
            let oh = conv_out_dim(h, kh, *stride, *pad)
                .ok_or_else(|| anyhow::anyhow!("conv2d: kernel {}x{} larger than input {}x{}", kh, kw, h, wd))?;
            let ow = conv_out_dim(wd, kw, *stride, *pad)
                .ok_or_else(|| anyhow::anyhow!("conv2d: kernel too large"))?;
            vec![TensorDesc::f32(&[n, co, oh, ow])]
        }
        MatMul { trans_a, trans_b, .. } => {
            let a = inputs[0];
            let b = inputs[1];
            anyhow::ensure!(a.rank() >= 2 && b.rank() >= 2, "matmul: rank >= 2 required");
            let (am, ak) = last2(a, *trans_a);
            let (bk, bn) = last2(b, *trans_b);
            anyhow::ensure!(ak == bk, "matmul: inner dims {} != {}", ak, bk);
            let batch = TensorDesc::broadcast(
                &a.shape[..a.rank() - 2],
                &b.shape[..b.rank() - 2],
            )
            .ok_or_else(|| anyhow::anyhow!("matmul: batch dims incompatible"))?;
            let mut shape = batch;
            shape.push(am);
            shape.push(bn);
            vec![TensorDesc::f32(&shape)]
        }
        Linear { .. } => {
            let x = inputs[0];
            let w = inputs[1];
            let b = inputs[2];
            anyhow::ensure!(x.rank() >= 2 && w.rank() == 2, "linear: x rank>=2, w rank 2");
            let k = *x.shape.last().unwrap();
            anyhow::ensure!(w.shape[0] == k, "linear: inner dims {} != {}", w.shape[0], k);
            anyhow::ensure!(b.shape == vec![w.shape[1]], "linear: bias shape mismatch");
            let mut shape = x.shape.clone();
            *shape.last_mut().unwrap() = w.shape[1];
            vec![TensorDesc::f32(&shape)]
        }
        Add | Mul => {
            let s = TensorDesc::broadcast(&inputs[0].shape, &inputs[1].shape)
                .ok_or_else(|| anyhow::anyhow!("{}: shapes {} vs {} not broadcastable", op.name(), inputs[0], inputs[1]))?;
            vec![TensorDesc { shape: s, dtype: inputs[0].dtype }]
        }
        AddN { .. } => {
            for i in 1..inputs.len() {
                anyhow::ensure!(inputs[i].shape == inputs[0].shape, "addn: all shapes must match");
            }
            vec![inputs[0].clone()]
        }
        Relu | Gelu | Sigmoid | Tanh | Identity => vec![inputs[0].clone()],
        Scale { .. } => vec![inputs[0].clone()],
        BatchNorm => {
            let x = inputs[0];
            anyhow::ensure!(x.rank() == 4, "batchnorm: NCHW input");
            let c = x.shape[1];
            anyhow::ensure!(inputs[1].shape == vec![c] && inputs[2].shape == vec![c], "batchnorm: scale/shift must be [C]");
            vec![x.clone()]
        }
        MaxPool { k, stride, pad } | AvgPool { k, stride, pad } => {
            let x = inputs[0];
            anyhow::ensure!(x.rank() == 4, "pool: NCHW input");
            let oh = conv_out_dim(x.shape[2], *k, *stride, *pad)
                .ok_or_else(|| anyhow::anyhow!("pool: window larger than input"))?;
            let ow = conv_out_dim(x.shape[3], *k, *stride, *pad)
                .ok_or_else(|| anyhow::anyhow!("pool: window larger than input"))?;
            vec![TensorDesc::f32(&[x.shape[0], x.shape[1], oh, ow])]
        }
        Concat { axis } => {
            let first = inputs[0];
            anyhow::ensure!(*axis < first.rank(), "concat: axis out of range");
            let mut dim = 0;
            for t in inputs {
                anyhow::ensure!(t.rank() == first.rank(), "concat: rank mismatch");
                for d in 0..t.rank() {
                    if d != *axis {
                        anyhow::ensure!(t.shape[d] == first.shape[d], "concat: non-axis dim mismatch");
                    }
                }
                dim += t.shape[*axis];
            }
            let mut shape = first.shape.clone();
            shape[*axis] = dim;
            vec![TensorDesc { shape, dtype: first.dtype }]
        }
        Split { axis, parts } => {
            let x = inputs[0];
            anyhow::ensure!(*axis < x.rank(), "split: axis out of range");
            anyhow::ensure!(*parts > 0 && x.shape[*axis] % parts == 0, "split: {} not divisible by {}", x.shape[*axis], parts);
            let mut shape = x.shape.clone();
            shape[*axis] /= parts;
            vec![TensorDesc { shape, dtype: x.dtype }; *parts]
        }
        Reshape { shape } => {
            let x = inputs[0];
            anyhow::ensure!(shape.iter().product::<usize>() == x.n_elems(), "reshape: {} elems -> {:?}", x.n_elems(), shape);
            vec![TensorDesc { shape: shape.clone(), dtype: x.dtype }]
        }
        Transpose { perm } => {
            let x = inputs[0];
            anyhow::ensure!(perm.len() == x.rank(), "transpose: perm rank mismatch");
            let mut seen = vec![false; perm.len()];
            for &p in perm {
                anyhow::ensure!(p < perm.len() && !seen[p], "transpose: invalid perm");
                seen[p] = true;
            }
            let shape: Vec<usize> = perm.iter().map(|&p| x.shape[p]).collect();
            vec![TensorDesc { shape, dtype: x.dtype }]
        }
        Softmax { axis } => {
            anyhow::ensure!(*axis < inputs[0].rank(), "softmax: axis out of range");
            vec![inputs[0].clone()]
        }
        LayerNorm => {
            let x = inputs[0];
            let d = *x.shape.last().ok_or_else(|| anyhow::anyhow!("layernorm: scalar input"))?;
            anyhow::ensure!(inputs[1].shape == vec![d] && inputs[2].shape == vec![d], "layernorm: gamma/beta must be [{}]", d);
            vec![x.clone()]
        }
        FusedAddLayerNorm => {
            let x = inputs[0];
            anyhow::ensure!(inputs[1].shape == x.shape, "fused_add_layernorm: x/y shape mismatch");
            let d = *x.shape.last().unwrap();
            anyhow::ensure!(inputs[2].shape == vec![d] && inputs[3].shape == vec![d], "fused_add_layernorm: gamma/beta must be [{}]", d);
            vec![x.clone()]
        }
        Enlarge { kh, kw } => {
            let w = inputs[0];
            anyhow::ensure!(w.rank() == 4, "enlarge: OIHW weight");
            anyhow::ensure!(*kh >= w.shape[2] && *kw >= w.shape[3], "enlarge: target smaller than kernel");
            anyhow::ensure!((kh - w.shape[2]) % 2 == 0 && (kw - w.shape[3]) % 2 == 0, "enlarge: padding must be symmetric");
            vec![TensorDesc { shape: vec![w.shape[0], w.shape[1], *kh, *kw], dtype: w.dtype }]
        }
    };
    debug_assert!(out.iter().all(|t| t.dtype == DType::F32 || t.dtype == DType::I32));
    Ok(out)
}

fn last2(t: &TensorDesc, trans: bool) -> (usize, usize) {
    let r = t.rank();
    let (m, n) = (t.shape[r - 2], t.shape[r - 1]);
    if trans {
        (n, m)
    } else {
        (m, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::Activation;

    fn d(shape: &[usize]) -> TensorDesc {
        TensorDesc::f32(shape)
    }

    #[test]
    fn conv_same_and_valid() {
        let x = d(&[1, 3, 32, 32]);
        let w = d(&[16, 3, 3, 3]);
        let op = OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None };
        assert_eq!(infer(&op, &[&x, &w]).unwrap()[0].shape, vec![1, 16, 32, 32]);
        let op2 = OpKind::Conv2d { stride: 2, pad: PadMode::Valid, act: Activation::None };
        assert_eq!(infer(&op2, &[&x, &w]).unwrap()[0].shape, vec![1, 16, 15, 15]);
    }

    #[test]
    fn conv_channel_mismatch_errors() {
        let x = d(&[1, 4, 8, 8]);
        let w = d(&[8, 3, 3, 3]);
        let op = OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None };
        assert!(infer(&op, &[&x, &w]).is_err());
    }

    #[test]
    fn matmul_batched_and_transposed() {
        let a = d(&[8, 12, 64, 64]);
        let b = d(&[64, 32]);
        let op = OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None };
        assert_eq!(infer(&op, &[&a, &b]).unwrap()[0].shape, vec![8, 12, 64, 32]);
        let bt = d(&[32, 64]);
        let op_t = OpKind::MatMul { trans_a: false, trans_b: true, act: Activation::None };
        assert_eq!(infer(&op_t, &[&a, &bt]).unwrap()[0].shape, vec![8, 12, 64, 32]);
    }

    #[test]
    fn split_and_concat_round_trip() {
        let x = d(&[2, 12, 64]);
        let outs = infer(&OpKind::Split { axis: 1, parts: 3 }, &[&x]).unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].shape, vec![2, 4, 64]);
        let refs: Vec<&TensorDesc> = outs.iter().collect();
        let back = infer(&OpKind::Concat { axis: 1 }, &refs).unwrap();
        assert_eq!(back[0].shape, x.shape);
    }

    #[test]
    fn split_indivisible_errors() {
        let x = d(&[2, 7, 4]);
        assert!(infer(&OpKind::Split { axis: 1, parts: 3 }, &[&x]).is_err());
    }

    #[test]
    fn transpose_validates_perm() {
        let x = d(&[2, 3, 4]);
        assert!(infer(&OpKind::Transpose { perm: vec![0, 0, 1] }, &[&x]).is_err());
        let ok = infer(&OpKind::Transpose { perm: vec![2, 0, 1] }, &[&x]).unwrap();
        assert_eq!(ok[0].shape, vec![4, 2, 3]);
    }

    #[test]
    fn enlarge_pads_kernel() {
        let w = d(&[16, 8, 3, 3]);
        let out = infer(&OpKind::Enlarge { kh: 5, kw: 5 }, &[&w]).unwrap();
        assert_eq!(out[0].shape, vec![16, 8, 5, 5]);
        assert!(infer(&OpKind::Enlarge { kh: 4, kw: 5 }, &[&w]).is_err()); // asymmetric
    }

    #[test]
    fn fused_add_layernorm_shape() {
        let x = d(&[2, 16, 64]);
        let g = d(&[64]);
        let out = infer(&OpKind::FusedAddLayerNorm, &[&x, &x, &g, &g]).unwrap();
        assert_eq!(out[0].shape, x.shape);
    }
}
