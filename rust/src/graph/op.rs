//! Operator vocabulary.
//!
//! The set covers everything the six evaluation graphs need (§4.2) plus the
//! fused operators that substitution rules introduce (`act` on conv/matmul,
//! `AddN`, `FusedAddLayerNorm`) — the transformer add/norm fusion of §4.10
//! is representable only because those fused forms exist.


#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    None,
    Relu,
    Gelu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PadMode {
    /// Output spatial size = ceil(in / stride).
    Same,
    /// No padding: out = floor((in - k) / stride) + 1.
    Valid,
}

/// One graph operator. Weights are graph nodes (`Weight`) so substitutions
/// can rewrite them (e.g. concatenating two conv kernels when merging).
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// External input tensor.
    Input,
    /// Trainable parameter (constant at optimisation time).
    Weight,
    /// 2-D convolution, NCHW x OIHW. Inputs: (x, w).
    Conv2d { stride: usize, pad: PadMode, act: Activation },
    /// Convolution with fused per-channel bias (BN-folded form).
    /// Inputs: (x, w, bias[C_out]).
    ConvBias { stride: usize, pad: PadMode, act: Activation },
    /// Matrix product over the last two dims (leading dims broadcast-batched).
    /// Inputs: (a, b).
    MatMul { trans_a: bool, trans_b: bool, act: Activation },
    /// x @ w + b with optional activation. Inputs: (x, w, b).
    Linear { act: Activation },
    /// Elementwise with numpy broadcasting. Inputs: (a, b).
    Add,
    Mul,
    /// n-ary elementwise sum of same-shape tensors (fusion product, §4.10).
    AddN { n: usize },
    Relu,
    Gelu,
    Sigmoid,
    Tanh,
    /// Inference-mode batch norm: per-channel scale/shift on NCHW.
    /// Inputs: (x, scale[C], shift[C]).
    BatchNorm,
    /// Inputs: (x,). Window pooling on NCHW.
    MaxPool { k: usize, stride: usize, pad: PadMode },
    AvgPool { k: usize, stride: usize, pad: PadMode },
    /// Concatenate along `axis`. Inputs: n tensors.
    Concat { axis: usize },
    /// Split into `parts` equal chunks along `axis`. One input, `parts` outputs.
    Split { axis: usize, parts: usize },
    Reshape { shape: Vec<usize> },
    Transpose { perm: Vec<usize> },
    Softmax { axis: usize },
    /// Layer normalisation over the last axis. Inputs: (x, gamma, beta).
    LayerNorm,
    /// layernorm(x + y) fused. Inputs: (x, y, gamma, beta). §4.10's win.
    FusedAddLayerNorm,
    /// Scalar multiply (attention scaling). Inputs: (x,). Factor is an attr.
    Scale { factor: f32 },
    /// TASO-style kernel enlargement: zero-pad a conv weight spatially to
    /// (kh, kw). Inputs: (w,).
    Enlarge { kh: usize, kw: usize },
    Identity,
}

/// Coarse operator classes used for the GNN one-hot feature (first feature
/// block) and for rule-generator alphabet grouping.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    Input,
    Weight,
    Conv,
    MatMul,
    Ewise,
    ActFn,
    Norm,
    Pool,
    Shape,
    Softmax,
    Fused,
    Other,
}

pub const N_OP_CLASSES: usize = 12;

impl OpKind {
    pub fn class(&self) -> OpClass {
        use OpKind::*;
        match self {
            Input => OpClass::Input,
            Weight => OpClass::Weight,
            Conv2d { .. } | ConvBias { .. } => OpClass::Conv,
            MatMul { .. } | Linear { .. } => OpClass::MatMul,
            Add | Mul | AddN { .. } | Scale { .. } => OpClass::Ewise,
            Relu | Gelu | Sigmoid | Tanh => OpClass::ActFn,
            BatchNorm | LayerNorm => OpClass::Norm,
            MaxPool { .. } | AvgPool { .. } => OpClass::Pool,
            Concat { .. } | Split { .. } | Reshape { .. } | Transpose { .. }
            | Enlarge { .. } | Identity => OpClass::Shape,
            Softmax { .. } => OpClass::Softmax,
            FusedAddLayerNorm => OpClass::Fused,
        }
    }

    pub fn class_index(&self) -> usize {
        use OpClass::*;
        match self.class() {
            Input => 0,
            Weight => 1,
            Conv => 2,
            MatMul => 3,
            Ewise => 4,
            ActFn => 5,
            Norm => 6,
            Pool => 7,
            Shape => 8,
            Softmax => 9,
            Fused => 10,
            Other => 11,
        }
    }

    /// Number of output ports.
    pub fn n_outputs(&self) -> usize {
        match self {
            OpKind::Split { parts, .. } => *parts,
            _ => 1,
        }
    }

    /// Expected input arity; `None` means variadic (validated elsewhere).
    pub fn arity(&self) -> Option<usize> {
        use OpKind::*;
        match self {
            Input | Weight => Some(0),
            Conv2d { .. } | MatMul { .. } | Add | Mul => Some(2),
            ConvBias { .. } | Linear { .. } | BatchNorm | LayerNorm => Some(3),
            FusedAddLayerNorm => Some(4),
            AddN { n } => Some(*n),
            Relu | Gelu | Sigmoid | Tanh | MaxPool { .. } | AvgPool { .. }
            | Split { .. } | Reshape { .. } | Transpose { .. } | Softmax { .. }
            | Scale { .. } | Enlarge { .. } | Identity => Some(1),
            Concat { .. } => None,
        }
    }

    /// Stable short name (serialisation + display + hashing).
    pub fn name(&self) -> &'static str {
        use OpKind::*;
        match self {
            Input => "input",
            Weight => "weight",
            Conv2d { .. } => "conv2d",
            ConvBias { .. } => "conv_bias",
            MatMul { .. } => "matmul",
            Linear { .. } => "linear",
            Add => "add",
            Mul => "mul",
            AddN { .. } => "addn",
            Relu => "relu",
            Gelu => "gelu",
            Sigmoid => "sigmoid",
            Tanh => "tanh",
            BatchNorm => "batchnorm",
            MaxPool { .. } => "maxpool",
            AvgPool { .. } => "avgpool",
            Concat { .. } => "concat",
            Split { .. } => "split",
            Reshape { .. } => "reshape",
            Transpose { .. } => "transpose",
            Softmax { .. } => "softmax",
            LayerNorm => "layernorm",
            FusedAddLayerNorm => "fused_add_layernorm",
            Scale { .. } => "scale",
            Enlarge { .. } => "enlarge",
            Identity => "identity",
        }
    }

    /// Attribute hash component (shape-independent).
    pub fn attr_hash(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.name().hash(&mut h);
        match self {
            OpKind::Conv2d { stride, pad, act } | OpKind::ConvBias { stride, pad, act } => {
                stride.hash(&mut h);
                (*pad as u8).hash(&mut h);
                (*act as u8).hash(&mut h);
            }
            OpKind::MatMul { trans_a, trans_b, act } => {
                trans_a.hash(&mut h);
                trans_b.hash(&mut h);
                (*act as u8).hash(&mut h);
            }
            OpKind::Linear { act } => (*act as u8).hash(&mut h),
            OpKind::AddN { n } => n.hash(&mut h),
            OpKind::MaxPool { k, stride, pad } | OpKind::AvgPool { k, stride, pad } => {
                k.hash(&mut h);
                stride.hash(&mut h);
                (*pad as u8).hash(&mut h);
            }
            OpKind::Concat { axis } | OpKind::Softmax { axis } => axis.hash(&mut h),
            OpKind::Split { axis, parts } => {
                axis.hash(&mut h);
                parts.hash(&mut h);
            }
            OpKind::Reshape { shape } => shape.hash(&mut h),
            OpKind::Transpose { perm } => perm.hash(&mut h),
            OpKind::Scale { factor } => factor.to_bits().hash(&mut h),
            OpKind::Enlarge { kh, kw } => {
                kh.hash(&mut h);
                kw.hash(&mut h);
            }
            _ => {}
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_matches_docs() {
        assert_eq!(OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None }.arity(), Some(2));
        assert_eq!(OpKind::FusedAddLayerNorm.arity(), Some(4));
        assert_eq!(OpKind::AddN { n: 5 }.arity(), Some(5));
        assert_eq!(OpKind::Concat { axis: 1 }.arity(), None);
    }

    #[test]
    fn split_has_multiple_outputs() {
        assert_eq!(OpKind::Split { axis: 1, parts: 3 }.n_outputs(), 3);
        assert_eq!(OpKind::Add.n_outputs(), 1);
    }

    #[test]
    fn attr_hash_distinguishes_attrs() {
        let a = OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None };
        let b = OpKind::Conv2d { stride: 2, pad: PadMode::Same, act: Activation::None };
        let c = OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::Relu };
        assert_ne!(a.attr_hash(), b.attr_hash());
        assert_ne!(a.attr_hash(), c.attr_hash());
        assert_eq!(a.attr_hash(), a.clone().attr_hash());
    }

    #[test]
    fn class_index_in_bounds() {
        for op in [
            OpKind::Input,
            OpKind::Weight,
            OpKind::Add,
            OpKind::Relu,
            OpKind::LayerNorm,
            OpKind::Softmax { axis: 1 },
            OpKind::FusedAddLayerNorm,
        ] {
            assert!(op.class_index() < N_OP_CLASSES);
        }
    }
}
