//! Canonical graph hashing (TASO §4's hash-based deduplication, Fig. 3).
//!
//! The hash must be invariant to (a) node-id numbering and (b) tensor
//! *names* — two graphs that differ only by renaming inputs hash equal
//! (Fig. 3a). Sources therefore hash by kind + shape only, with a
//! multiplicity-disambiguation pass so structurally distinct uses of
//! same-shaped inputs still separate where the wiring differs.
//!
//! The disambiguation is one round of Weisfeiler–Lehman-style refinement
//! on the source nodes: each source folds in the sorted multiset of
//! `(consumer op attrs, input slot, port)` triples over its live consumer
//! edges. Renaming two sources is a structure-preserving bijection, so
//! their refined hashes swap along with them (still Fig. 3a-invariant),
//! while `add(x, x)` and `add(x, y)` — identical under shape-only source
//! hashing — now separate: the former's single source carries both
//! consumer slots. Without this the substitution generator's
//! canonical-hash dedup silently merged semantically distinct enumerants
//! (`x + x` is `2x`, not `x + y`), deflating the candidate pool.

use super::graph::Graph;
use super::op::OpKind;

fn mix(a: u64, b: u64) -> u64 {
    // 64-bit finalizer-style mixing; order-sensitive.
    let mut x = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

fn shape_hash(shape: &[usize]) -> u64 {
    let mut h = 0xCBF29CE484222325;
    for &d in shape {
        h = mix(h, d as u64);
    }
    h
}

/// Canonical hash of the live subgraph.
///
/// Per-node hashes are computed bottom-up: a node's hash combines its op
/// attr-hash with the ordered (hash, port) pairs of its inputs; the graph
/// hash combines the *sorted* multiset of output-node hashes, so output
/// enumeration order does not matter. An op node's hash depends only on
/// its ancestors (sources additionally fold in their consumer-edge
/// context, computed in a separate pre-pass), so any topological
/// processing order yields the same value.
///
/// This runs once per search candidate (it keys the transposition table in
/// `crate::search`), so it avoids the HashMap-based `Graph::topo_order` /
/// `Graph::consumers` helpers in favour of flat arena-indexed vectors: an
/// in-degree worklist over a CSR consumer layout.
pub fn canonical_hash(g: &Graph) -> u64 {
    let n = g.n_slots();
    let mut live = vec![false; n];
    let mut indeg = vec![0u32; n];
    // CSR consumer adjacency: head[i]..head[i+1] indexes `edges`, one entry
    // per (consumer, input-slot) edge, matching the per-edge in-degrees.
    let mut head = vec![0u32; n + 1];
    for id in g.live_ids() {
        let i = id.index();
        live[i] = true;
        indeg[i] = g.node(id).inputs.len() as u32;
        for inp in &g.node(id).inputs {
            head[inp.node.index() + 1] += 1;
        }
    }
    for i in 0..n {
        head[i + 1] += head[i];
    }
    let mut edges = vec![0u32; head[n] as usize];
    let mut cursor: Vec<u32> = head[..n].to_vec();
    for id in g.live_ids() {
        for inp in &g.node(id).inputs {
            let p = inp.node.index();
            edges[cursor[p] as usize] = id.0;
            cursor[p] += 1;
        }
    }

    // Multiplicity disambiguation: per-source context = sorted multiset of
    // (consumer attrs, input slot, port) over live consumer edges. Pure
    // renamings keep per-source contexts (the bijection maps consumer
    // edges exactly), while distinct wirings of same-shaped sources —
    // add(x, x) vs add(x, y) — get distinct source hashes.
    let mut src_edges: Vec<(usize, u64)> = Vec::new();
    for id in g.live_ids() {
        let node = g.node(id);
        for (slot, inp) in node.inputs.iter().enumerate() {
            let p = inp.node.index();
            if matches!(g.nodes[p].op, OpKind::Input | OpKind::Weight) {
                let e = mix(node.op.attr_hash(), mix(slot as u64, inp.port as u64));
                src_edges.push((p, e));
            }
        }
    }
    src_edges.sort_unstable();
    let mut src_ctx = vec![0x5151_5151u64; n];
    for (p, e) in src_edges {
        src_ctx[p] = mix(src_ctx[p], e);
    }

    let mut queue: Vec<u32> = (0..n as u32)
        .filter(|&i| live[i as usize] && indeg[i as usize] == 0)
        .collect();
    let mut node_hash = vec![0u64; n];
    let mut qi = 0;
    while qi < queue.len() {
        let idx = queue[qi] as usize;
        qi += 1;
        let node = &g.nodes[idx];
        let mut h = match node.op {
            // Name-invariance: sources hash by kind + shape + the
            // consumer-edge context computed above (never by id).
            OpKind::Input => mix(0x1111, mix(shape_hash(&node.outs[0].shape), src_ctx[idx])),
            OpKind::Weight => mix(0x2222, mix(shape_hash(&node.outs[0].shape), src_ctx[idx])),
            _ => node.op.attr_hash(),
        };
        for inp in &node.inputs {
            h = mix(h, mix(node_hash[inp.node.index()], inp.port as u64));
        }
        node_hash[idx] = h;
        for &c in &edges[head[idx] as usize..head[idx + 1] as usize] {
            indeg[c as usize] -= 1;
            if indeg[c as usize] == 0 {
                queue.push(c);
            }
        }
    }
    if qi != g.n_live() {
        return 0; // cycle: invalid graphs all hash to 0
    }

    // Outputs: live non-source nodes with no live consumers.
    let mut outs: Vec<u64> = (0..n)
        .filter(|&i| {
            live[i]
                && !matches!(g.nodes[i].op, OpKind::Input | OpKind::Weight)
                && head[i] == head[i + 1]
        })
        .map(|i| node_hash[i])
        .collect();
    outs.sort_unstable();
    let mut h = 0x9E3779B97F4A7C15;
    for o in outs {
        h = mix(h, o);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::{Activation, PadMode};
    use crate::graph::tensor::TensorDesc;
    use crate::graph::PortRef;

    fn mm(g: &mut Graph, a: PortRef, b: PortRef) -> PortRef {
        PortRef::of(
            g.add(
                OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
                &[a, b],
            )
            .unwrap(),
        )
    }

    #[test]
    fn insensitive_to_build_order() {
        // g1: weights then input; g2: input then weights — same structure.
        let mut g1 = Graph::new();
        let w1 = PortRef::of(g1.add_source(OpKind::Weight, TensorDesc::f32(&[8, 8])));
        let x1 = PortRef::of(g1.add_source(OpKind::Input, TensorDesc::f32(&[4, 8])));
        mm(&mut g1, x1, w1);

        let mut g2 = Graph::new();
        let x2 = PortRef::of(g2.add_source(OpKind::Input, TensorDesc::f32(&[4, 8])));
        let w2 = PortRef::of(g2.add_source(OpKind::Weight, TensorDesc::f32(&[8, 8])));
        mm(&mut g2, x2, w2);

        assert_eq!(canonical_hash(&g1), canonical_hash(&g2));
    }

    #[test]
    fn sensitive_to_structure() {
        let mut g1 = Graph::new();
        let x = PortRef::of(g1.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let y = PortRef::of(g1.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        g1.add(OpKind::Add, &[x, y]).unwrap();

        let mut g2 = Graph::new();
        let x2 = PortRef::of(g2.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let y2 = PortRef::of(g2.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        g2.add(OpKind::Mul, &[x2, y2]).unwrap();

        assert_ne!(canonical_hash(&g1), canonical_hash(&g2));
    }

    #[test]
    fn sensitive_to_attrs() {
        let build = |stride: usize| {
            let mut g = Graph::new();
            let x = PortRef::of(g.add_source(OpKind::Input, TensorDesc::f32(&[1, 3, 8, 8])));
            let w = PortRef::of(g.add_source(OpKind::Weight, TensorDesc::f32(&[4, 3, 3, 3])));
            g.add(
                OpKind::Conv2d { stride, pad: PadMode::Same, act: Activation::None },
                &[x, w],
            )
            .unwrap();
            g
        };
        assert_ne!(canonical_hash(&build(1)), canonical_hash(&build(2)));
    }

    #[test]
    fn same_shaped_sources_separate_by_wiring() {
        // add(x, y) and add(x, x) must NOT hash equal: the former reads two
        // distinct sources, the latter one source twice (x + x == 2x).
        let mut g1 = Graph::new();
        let x = PortRef::of(g1.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let y = PortRef::of(g1.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        g1.add(OpKind::Add, &[x, y]).unwrap();

        let mut g2 = Graph::new();
        let x2 = PortRef::of(g2.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let _y2 = g2.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        g2.add(OpKind::Add, &[x2, x2]).unwrap();

        assert_ne!(canonical_hash(&g1), canonical_hash(&g2));
    }

    #[test]
    fn renaming_sources_still_merges() {
        // add(x, y) vs add(y, x): swapping the two same-shaped sources is a
        // pure renaming — the refinement must keep them hash-equal.
        let mut g1 = Graph::new();
        let x = PortRef::of(g1.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let y = PortRef::of(g1.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        g1.add(OpKind::Add, &[x, y]).unwrap();

        let mut g2 = Graph::new();
        let x2 = PortRef::of(g2.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let y2 = PortRef::of(g2.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        g2.add(OpKind::Add, &[y2, x2]).unwrap();

        assert_eq!(canonical_hash(&g1), canonical_hash(&g2));

        // And a deeper asymmetric wiring still separates: add(mul(x, y), x)
        // vs add(mul(x, y), y) read different sources at the add's slot 1.
        let build = |second_is_x: bool| {
            let mut g = Graph::new();
            let a = PortRef::of(g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
            let b = PortRef::of(g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
            let m = PortRef::of(g.add(OpKind::Mul, &[a, b]).unwrap());
            g.add(OpKind::Add, &[m, if second_is_x { a } else { b }]).unwrap();
            g
        };
        assert_ne!(canonical_hash(&build(true)), canonical_hash(&build(false)));
    }

    #[test]
    fn dead_nodes_do_not_contribute() {
        let mut g = Graph::new();
        let x = PortRef::of(g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let a = g.add(OpKind::Relu, &[x]).unwrap();
        let h1 = canonical_hash(&g);
        // Add then kill an unrelated node.
        let t = g.add(OpKind::Tanh, &[x]).unwrap();
        g.kill(t);
        let _ = a;
        assert_eq!(canonical_hash(&g), h1);
    }

    #[test]
    fn compaction_preserves_hash() {
        let mut g = Graph::new();
        let x = PortRef::of(g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4])));
        let r = g.add(OpKind::Relu, &[x]).unwrap();
        let t = g.add(OpKind::Tanh, &[PortRef::of(r)]).unwrap();
        let _ = t;
        let h1 = canonical_hash(&g);
        let (g2, _) = g.compact().unwrap();
        assert_eq!(canonical_hash(&g2), h1);
    }
}
