//! The computation-graph DAG.
//!
//! Nodes live in an arena indexed by [`NodeId`]; removal tombstones the slot
//! (`dead = true`) so ids held by substitution matches stay valid for the
//! lifetime of one environment step. [`Graph::compact`] renumbers when a
//! fresh canonical copy is needed (hashing, serialisation, episodes reset).

use std::collections::HashMap;


use super::op::OpKind;
use super::shapes;
use super::tensor::TensorDesc;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A reference to one output port of a node (multi-output ops: `Split`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PortRef {
    pub node: NodeId,
    pub port: u16,
}

impl PortRef {
    pub fn of(node: NodeId) -> Self {
        Self { node, port: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub op: OpKind,
    pub inputs: Vec<PortRef>,
    pub outs: Vec<TensorDesc>,
    pub dead: bool,
}

#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub(crate) nodes: Vec<Node>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- construction -----------------------------------------------------

    /// Add a source node (Input / Weight) with an explicit descriptor.
    pub fn add_source(&mut self, op: OpKind, desc: TensorDesc) -> NodeId {
        debug_assert!(matches!(op, OpKind::Input | OpKind::Weight));
        self.push(Node { op, inputs: vec![], outs: vec![desc], dead: false })
    }

    /// Add an operator node; output shapes are inferred and validated.
    pub fn add(&mut self, op: OpKind, inputs: &[PortRef]) -> anyhow::Result<NodeId> {
        let descs: Vec<&TensorDesc> = inputs
            .iter()
            .map(|p| self.out_desc(*p))
            .collect::<anyhow::Result<_>>()?;
        let outs = shapes::infer(&op, &descs)?;
        Ok(self.push(Node { op, inputs: inputs.to_vec(), outs, dead: false }))
    }

    fn push(&mut self, node: Node) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(node);
        id
    }

    // ---- access -------------------------------------------------------------

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.index()]
    }

    pub fn out_desc(&self, p: PortRef) -> anyhow::Result<&TensorDesc> {
        let n = self
            .nodes
            .get(p.node.index())
            .ok_or_else(|| anyhow::anyhow!("dangling node id {:?}", p.node))?;
        anyhow::ensure!(!n.dead, "reference to dead node {:?}", p.node);
        n.outs
            .get(p.port as usize)
            .ok_or_else(|| anyhow::anyhow!("port {} out of range for {:?}", p.port, p.node))
    }

    /// Iterate live node ids.
    pub fn live_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| !n.dead)
            .map(|(i, _)| NodeId(i as u32))
    }

    pub fn n_live(&self) -> usize {
        self.nodes.iter().filter(|n| !n.dead).count()
    }

    /// Number of live nodes excluding Input/Weight sources ("ops").
    pub fn n_ops(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| !n.dead && !matches!(n.op, OpKind::Input | OpKind::Weight))
            .count()
    }

    /// consumers[node] = list of (consumer id, consumer's input slot).
    ///
    /// HashMap form, kept for cold callers; the hot paths (matcher, state
    /// encoder, costing, topo order) use the dense arena-indexed
    /// [`Graph::consumers_vec`].
    pub fn consumers(&self) -> HashMap<NodeId, Vec<(NodeId, usize)>> {
        let mut map: HashMap<NodeId, Vec<(NodeId, usize)>> = HashMap::new();
        for id in self.live_ids() {
            for (slot, inp) in self.node(id).inputs.iter().enumerate() {
                map.entry(inp.node).or_default().push((id, slot));
            }
        }
        map
    }

    /// Dense consumer lists indexed by arena slot (`NodeId::index`): entry
    /// `i` lists `(consumer id, consumer's input slot)` for node `i`; dead
    /// slots hold empty lists. Because live ids are visited in ascending
    /// order and inputs in slot order, each list is already sorted by
    /// `(consumer id, slot)` — the order [`sorted_consumers`] produces.
    ///
    /// [`sorted_consumers`]: crate::xfer::matcher::sorted_consumers
    pub fn consumers_vec(&self) -> Vec<Vec<(NodeId, usize)>> {
        let mut cons: Vec<Vec<(NodeId, usize)>> = vec![Vec::new(); self.nodes.len()];
        for id in self.live_ids() {
            for (slot, inp) in self.node(id).inputs.iter().enumerate() {
                cons[inp.node.index()].push((id, slot));
            }
        }
        cons
    }

    /// Live nodes with no live consumers (excluding sources): graph outputs.
    pub fn output_ids(&self) -> Vec<NodeId> {
        let cons = self.consumers_vec();
        self.live_ids()
            .filter(|id| {
                !matches!(self.node(*id).op, OpKind::Input | OpKind::Weight)
                    && cons[id.index()].is_empty()
            })
            .collect()
    }

    /// Topological order of live nodes (sources first). Errors on cycles.
    pub fn topo_order(&self) -> anyhow::Result<Vec<NodeId>> {
        // Dense arena-indexed working state (indeg < 0 marks dead slots);
        // initial zero-indegree queue in ascending id order, then consumer
        // discovery order — the same order the seed HashMap walk produced.
        let cons = self.consumers_vec();
        let mut indeg: Vec<isize> = vec![-1; self.nodes.len()];
        let mut n_live = 0usize;
        let mut queue: Vec<NodeId> = Vec::new();
        for id in self.live_ids() {
            let d = self.node(id).inputs.len();
            indeg[id.index()] = d as isize;
            n_live += 1;
            if d == 0 {
                queue.push(id);
            }
        }
        let mut order = Vec::with_capacity(n_live);
        let mut qi = 0;
        while qi < queue.len() {
            let id = queue[qi];
            qi += 1;
            order.push(id);
            // A consumer may reference `id` in several slots; decrement per edge.
            for (c, _) in &cons[id.index()] {
                let d = &mut indeg[c.index()];
                *d -= 1;
                if *d == 0 {
                    queue.push(*c);
                }
            }
        }
        anyhow::ensure!(order.len() == n_live, "cycle detected in graph");
        Ok(order)
    }

    // ---- mutation ---------------------------------------------------------

    /// Redirect every consumer of `from` to read `to` instead.
    pub fn replace_uses(&mut self, from: PortRef, to: PortRef) {
        for n in self.nodes.iter_mut().filter(|n| !n.dead) {
            for inp in n.inputs.iter_mut() {
                if *inp == from {
                    *inp = to;
                }
            }
        }
    }

    pub fn kill(&mut self, id: NodeId) {
        self.nodes[id.index()].dead = true;
    }

    /// Remove nodes not reachable (as ancestors) from any graph output.
    pub fn dce(&mut self) {
        let outputs = self.output_ids();
        let mut alive = vec![false; self.nodes.len()];
        let mut stack = outputs;
        while let Some(id) = stack.pop() {
            if alive[id.index()] {
                continue;
            }
            alive[id.index()] = true;
            for inp in &self.node(id).inputs {
                stack.push(inp.node);
            }
        }
        for (i, n) in self.nodes.iter_mut().enumerate() {
            if !alive[i] {
                n.dead = true;
            }
        }
    }

    /// Rebuild a dense graph with dead slots dropped and ids renumbered in
    /// topological order. Returns the new graph and old->new id map.
    ///
    /// Fast path: a graph with no dead slots whose edges all point to
    /// lower arena indices (true for every builder-produced or previously
    /// compacted graph) is already a dense topological numbering, so the
    /// result is a plain clone with the identity map and the topo sort is
    /// skipped entirely. Note the fast path *keeps* the existing valid
    /// numbering rather than re-deriving the Kahn order the slow path
    /// produces — both are topological, but a forward-ordered graph that
    /// interleaves sources with ops keeps its interleaved ids instead of
    /// having sources renumbered first.
    pub fn compact(&self) -> anyhow::Result<(Graph, HashMap<NodeId, NodeId>)> {
        let forward_ordered = self
            .nodes
            .iter()
            .enumerate()
            .all(|(i, n)| !n.dead && n.inputs.iter().all(|p| p.node.index() < i));
        if forward_ordered {
            let map: HashMap<NodeId, NodeId> =
                (0..self.nodes.len() as u32).map(|i| (NodeId(i), NodeId(i))).collect();
            return Ok((self.clone(), map));
        }
        let order = self.topo_order()?;
        let mut map = HashMap::new();
        let mut g = Graph::new();
        for id in order {
            let n = self.node(id);
            let inputs: Vec<PortRef> = n
                .inputs
                .iter()
                .map(|p| PortRef { node: map[&p.node], port: p.port })
                .collect();
            let new_id = g.push(Node {
                op: n.op.clone(),
                inputs,
                outs: n.outs.clone(),
                dead: false,
            });
            map.insert(id, new_id);
        }
        Ok((g, map))
    }

    /// Structural validation: acyclic, ports in range, shapes re-infer to
    /// the stored descriptors. Used by tests and after every substitution.
    pub fn validate(&self) -> anyhow::Result<()> {
        let _ = self.topo_order()?;
        for id in self.live_ids() {
            let n = self.node(id);
            if matches!(n.op, OpKind::Input | OpKind::Weight) {
                anyhow::ensure!(n.inputs.is_empty(), "source with inputs at {:?}", id);
                continue;
            }
            let descs: Vec<&TensorDesc> = n
                .inputs
                .iter()
                .map(|p| self.out_desc(*p))
                .collect::<anyhow::Result<_>>()?;
            let outs = shapes::infer(&n.op, &descs)?;
            anyhow::ensure!(
                outs == n.outs,
                "stored shapes stale at {:?}: {:?} vs {:?}",
                id,
                n.outs,
                outs
            );
        }
        Ok(())
    }

    /// Depth (longest path length from any source) per live node.
    ///
    /// HashMap form, kept for cold callers; hot paths use the dense
    /// [`Graph::depths_vec`].
    pub fn depths(&self) -> HashMap<NodeId, usize> {
        let dense = self.depths_vec();
        self.live_ids().map(|id| (id, dense[id.index()])).collect()
    }

    /// Depth per arena slot (`NodeId::index`), 0 for dead slots. Dense
    /// variant of [`Graph::depths`] for the encoder/matcher hot paths.
    pub fn depths_vec(&self) -> Vec<usize> {
        let mut depth = vec![0usize; self.nodes.len()];
        if let Ok(order) = self.topo_order() {
            for id in order {
                let d = self
                    .node(id)
                    .inputs
                    .iter()
                    .map(|p| depth[p.node.index()] + 1)
                    .max()
                    .unwrap_or(0);
                depth[id.index()] = d;
            }
        }
        depth
    }
}

impl std::fmt::Display for Graph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for id in self.live_ids() {
            let n = self.node(id);
            let ins: Vec<String> = n
                .inputs
                .iter()
                .map(|p| format!("%{}.{}", p.node.0, p.port))
                .collect();
            let outs: Vec<String> = n.outs.iter().map(|t| t.to_string()).collect();
            writeln!(
                f,
                "%{} = {}({}) -> {}",
                id.0,
                n.op.name(),
                ins.join(", "),
                outs.join(", ")
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::Activation;
    use crate::graph::PadMode;

    fn small() -> (Graph, NodeId, NodeId) {
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[1, 8, 16, 16]));
        let w = g.add_source(OpKind::Weight, TensorDesc::f32(&[16, 8, 3, 3]));
        let c = g
            .add(
                OpKind::Conv2d { stride: 1, pad: PadMode::Same, act: Activation::None },
                &[PortRef::of(x), PortRef::of(w)],
            )
            .unwrap();
        let r = g.add(OpKind::Relu, &[PortRef::of(c)]).unwrap();
        (g, c, r)
    }

    #[test]
    fn build_and_validate() {
        let (g, _, _) = small();
        g.validate().unwrap();
        assert_eq!(g.n_live(), 4);
        assert_eq!(g.n_ops(), 2);
    }

    #[test]
    fn outputs_are_sinks() {
        let (g, _, r) = small();
        assert_eq!(g.output_ids(), vec![r]);
    }

    #[test]
    fn topo_order_parents_first() {
        let (g, _, _) = small();
        let order = g.topo_order().unwrap();
        let pos: HashMap<NodeId, usize> =
            order.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        for id in g.live_ids() {
            for inp in &g.node(id).inputs {
                assert!(pos[&inp.node] < pos[&id]);
            }
        }
    }

    #[test]
    fn dce_removes_orphaned_weights() {
        // Killing an op strands its weight; DCE must clean the weight up
        // (weights are never graph outputs).
        let (mut g, c, r) = small();
        g.kill(r);
        g.kill(c);
        g.dce();
        let w = NodeId(1);
        assert!(g.node(w).dead, "orphan weight should be collected");
        // The input is also unreachable from any output now.
        assert!(g.node(NodeId(0)).dead);
    }

    #[test]
    fn compact_renumbers_dense() {
        let mut g = Graph::new();
        let x = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let dead_relu = g.add(OpKind::Relu, &[PortRef::of(x)]).unwrap();
        let live_tanh = g.add(OpKind::Tanh, &[PortRef::of(x)]).unwrap();
        g.kill(dead_relu);
        let (g2, map) = g.compact().unwrap();
        assert_eq!(g2.n_live(), 2);
        assert!(g2.nodes.iter().all(|n| !n.dead));
        assert!(!map.contains_key(&dead_relu));
        assert!(map.contains_key(&live_tanh));
        g2.validate().unwrap();
    }

    #[test]
    fn replace_uses_rewires_all() {
        let mut g = Graph::new();
        let a = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let b = g.add_source(OpKind::Input, TensorDesc::f32(&[4, 4]));
        let s1 = g.add(OpKind::Add, &[PortRef::of(a), PortRef::of(a)]).unwrap();
        g.replace_uses(PortRef::of(a), PortRef::of(b));
        assert_eq!(g.node(s1).inputs, vec![PortRef::of(b), PortRef::of(b)]);
    }

    #[test]
    fn cycle_is_detected() {
        let (mut g, c, r) = small();
        // Create a cycle: conv reads relu.
        g.node_mut(c).inputs[0] = PortRef::of(r);
        assert!(g.topo_order().is_err());
        assert!(g.validate().is_err());
    }

    #[test]
    fn depths_increase_along_edges() {
        let (g, c, r) = small();
        let d = g.depths();
        assert_eq!(d[&NodeId(0)], 0);
        assert_eq!(d[&c], 1);
        assert_eq!(d[&r], 2);
    }

    #[test]
    fn dense_helpers_agree_with_map_versions() {
        let (mut g, c, _) = small();
        let extra = g.add(OpKind::Tanh, &[PortRef::of(c)]).unwrap();
        g.kill(extra); // a dead slot exercises the empty-list case
        let cons_map = g.consumers();
        let cons_vec = g.consumers_vec();
        assert_eq!(cons_vec.len(), 5);
        for id in g.live_ids() {
            let want = cons_map.get(&id).cloned().unwrap_or_default();
            assert_eq!(cons_vec[id.index()], want, "consumers differ at {id:?}");
            // The dense lists come out pre-sorted by (consumer, slot).
            assert!(cons_vec[id.index()].windows(2).all(|w| w[0] <= w[1]));
        }
        assert!(cons_vec[extra.index()].is_empty());
        let d_map = g.depths();
        let d_vec = g.depths_vec();
        for id in g.live_ids() {
            assert_eq!(d_map[&id], d_vec[id.index()], "depths differ at {id:?}");
        }
        assert_eq!(d_vec[extra.index()], 0);
    }

    #[test]
    fn compact_short_circuits_dense_graphs_to_identity() {
        // Builder graphs have no dead slots and forward-only edges, so
        // compaction is a clone + identity map.
        let (g, _, _) = small();
        let (g2, map) = g.compact().unwrap();
        assert_eq!(g2.n_live(), g.n_live());
        for id in g.live_ids() {
            assert_eq!(map[&id], id, "dense graph must map identically");
            assert_eq!(g2.node(id).inputs, g.node(id).inputs);
        }
        g2.validate().unwrap();
        // With a dead slot the full renumbering path still runs.
        let mut g3 = g.clone();
        g3.kill(NodeId(3));
        g3.dce();
        let (g4, map4) = g3.compact().unwrap();
        assert!(g4.nodes.iter().all(|n| !n.dead));
        assert!(map4.len() < g3.nodes.len());
    }
}
