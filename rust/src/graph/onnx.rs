//! ONNX-style JSON serialisation (§3.1.2).
//!
//! The paper ingests models via the ONNX binary format; we serialise the
//! same information (ops, attributes, tensor descriptors, edges) as JSON —
//! an open, diffable stand-in that round-trips every graph in the zoo and
//! lets optimised graphs be exported for inspection (`rlflow optimize
//! --export out.json`).
//!
//! Format sketch:
//! ```json
//! { "ir_version": 1, "producer": "rlflow", "graph_name": "bert",
//!   "nodes": [ {"op": "conv2d", "stride": 1, "pad": "same", "act": "relu",
//!               "inputs": [[0,0],[1,0]], "outs": [{"dtype":"f32","shape":[1,16,32,32]}]} ] }
//! ```
//!
//! # Untrusted input
//!
//! [`import`] is the decode path for the `rlflow serve` daemon and for
//! ruleset files, so it must return `Err` — never panic — on arbitrary
//! bytes. Beyond the structural checks (forward-only references, stored
//! shapes re-inferred), every attribute and descriptor is bounded before it
//! reaches shape inference: tensor ranks and element counts
//! ([`MAX_RANK`]/[`MAX_ELEMS`], checked multiplication — a `[1e15,1e15]`
//! descriptor errors instead of overflowing `n_elems`), window/stride
//! attributes ([`MAX_ATTR_DIM`], strides >= 1 so output-dim division cannot
//! divide by zero), fan-in and node counts ([`MAX_NODE_INPUTS`] /
//! [`MAX_NODES`]), and port indices (must fit `u16` rather than silently
//! truncating). `tests/onnx_robust.rs` fuzzes this contract.

use crate::util::json::{parse, Json};

use super::graph::{Graph, NodeId, PortRef};
use super::op::{Activation, OpKind, PadMode};
use super::tensor::{DType, TensorDesc};

// ---------------------------------------------------------------------------
// Resource bounds for untrusted input
// ---------------------------------------------------------------------------

/// Maximum nodes an imported model may declare.
pub const MAX_NODES: usize = 1 << 20;
/// Maximum inputs (fan-in) a single imported node may declare.
pub const MAX_NODE_INPUTS: usize = 64;
/// Maximum output descriptors a single imported node may declare.
pub const MAX_NODE_OUTS: usize = 4096;
/// Maximum tensor rank an imported descriptor may declare.
pub const MAX_RANK: usize = 8;
/// Maximum elements an imported tensor descriptor may describe (2^40).
/// Checked with `checked_mul`, so absurd dimensions error instead of
/// overflowing downstream `n_elems`/FLOP products.
pub const MAX_ELEMS: usize = 1 << 40;
/// Maximum value for scalar window/stride/padding-style attributes
/// (`stride`, `k`, `kh`, `kw`).
pub const MAX_ATTR_DIM: usize = 1 << 20;

/// Bounded element count of a dimension list, or `Err` when the rank or
/// the (checked) product exceeds the import limits.
fn checked_numel(dims: &[usize], what: &str) -> anyhow::Result<usize> {
    anyhow::ensure!(
        (1..=MAX_RANK).contains(&dims.len()),
        "{}: rank {} outside 1..={}",
        what,
        dims.len(),
        MAX_RANK
    );
    let mut n: usize = 1;
    for &d in dims {
        anyhow::ensure!(d > 0, "{}: zero-sized dimension", what);
        n = n
            .checked_mul(d)
            .ok_or_else(|| anyhow::anyhow!("{}: element count overflows", what))?;
        anyhow::ensure!(n <= MAX_ELEMS, "{}: {} elements exceeds limit", what, n);
    }
    Ok(n)
}

// ---------------------------------------------------------------------------
// OpKind <-> JSON
// ---------------------------------------------------------------------------

fn act_str(a: Activation) -> &'static str {
    match a {
        Activation::None => "none",
        Activation::Relu => "relu",
        Activation::Gelu => "gelu",
    }
}

fn act_parse(s: &str) -> anyhow::Result<Activation> {
    Ok(match s {
        "none" => Activation::None,
        "relu" => Activation::Relu,
        "gelu" => Activation::Gelu,
        _ => anyhow::bail!("unknown activation '{}'", s),
    })
}

fn pad_str(p: PadMode) -> &'static str {
    match p {
        PadMode::Same => "same",
        PadMode::Valid => "valid",
    }
}

fn pad_parse(s: &str) -> anyhow::Result<PadMode> {
    Ok(match s {
        "same" => PadMode::Same,
        "valid" => PadMode::Valid,
        _ => anyhow::bail!("unknown pad mode '{}'", s),
    })
}

pub fn op_to_json(op: &OpKind) -> Json {
    let mut j = Json::obj();
    j.set("op", Json::Str(op.name().into()));
    match op {
        OpKind::Conv2d { stride, pad, act } | OpKind::ConvBias { stride, pad, act } => {
            j.set("stride", Json::Num(*stride as f64));
            j.set("pad", Json::Str(pad_str(*pad).into()));
            j.set("act", Json::Str(act_str(*act).into()));
        }
        OpKind::MatMul { trans_a, trans_b, act } => {
            j.set("trans_a", Json::Bool(*trans_a));
            j.set("trans_b", Json::Bool(*trans_b));
            j.set("act", Json::Str(act_str(*act).into()));
        }
        OpKind::Linear { act } => {
            j.set("act", Json::Str(act_str(*act).into()));
        }
        OpKind::AddN { n } => {
            j.set("n", Json::Num(*n as f64));
        }
        OpKind::MaxPool { k, stride, pad } | OpKind::AvgPool { k, stride, pad } => {
            j.set("k", Json::Num(*k as f64));
            j.set("stride", Json::Num(*stride as f64));
            j.set("pad", Json::Str(pad_str(*pad).into()));
        }
        OpKind::Concat { axis } | OpKind::Softmax { axis } => {
            j.set("axis", Json::Num(*axis as f64));
        }
        OpKind::Split { axis, parts } => {
            j.set("axis", Json::Num(*axis as f64));
            j.set("parts", Json::Num(*parts as f64));
        }
        OpKind::Reshape { shape } => {
            j.set("shape", Json::from_usizes(shape));
        }
        OpKind::Transpose { perm } => {
            j.set("perm", Json::from_usizes(perm));
        }
        OpKind::Scale { factor } => {
            j.set("factor", Json::Num(*factor as f64));
        }
        OpKind::Enlarge { kh, kw } => {
            j.set("kh", Json::Num(*kh as f64));
            j.set("kw", Json::Num(*kw as f64));
        }
        _ => {}
    }
    j
}

pub fn op_from_json(j: &Json) -> anyhow::Result<OpKind> {
    let name = j.get("op")?.as_str()?;
    // A scalar attribute in 1..=MAX_ATTR_DIM: window sizes, strides and
    // padding targets must be positive (stride 0 would divide by zero in
    // `conv_out_dim`) and sane.
    let dim_attr = |key: &str| -> anyhow::Result<usize> {
        let v = j.get(key)?.as_usize()?;
        anyhow::ensure!(
            (1..=MAX_ATTR_DIM).contains(&v),
            "attribute '{}' = {} outside 1..={}",
            key,
            v,
            MAX_ATTR_DIM
        );
        Ok(v)
    };
    // Axis-style attributes only need to fit a sane rank; range against the
    // actual input rank is shape inference's job.
    let axis_attr = |key: &str| -> anyhow::Result<usize> {
        let v = j.get(key)?.as_usize()?;
        anyhow::ensure!(v < MAX_RANK, "attribute '{}' = {} outside 0..{}", key, v, MAX_RANK);
        Ok(v)
    };
    Ok(match name {
        "input" => OpKind::Input,
        "weight" => OpKind::Weight,
        "conv_bias" => OpKind::ConvBias {
            stride: dim_attr("stride")?,
            pad: pad_parse(j.get("pad")?.as_str()?)?,
            act: act_parse(j.get("act")?.as_str()?)?,
        },
        "conv2d" => OpKind::Conv2d {
            stride: dim_attr("stride")?,
            pad: pad_parse(j.get("pad")?.as_str()?)?,
            act: act_parse(j.get("act")?.as_str()?)?,
        },
        "matmul" => OpKind::MatMul {
            trans_a: j.get("trans_a")?.as_bool()?,
            trans_b: j.get("trans_b")?.as_bool()?,
            act: act_parse(j.get("act")?.as_str()?)?,
        },
        "linear" => OpKind::Linear { act: act_parse(j.get("act")?.as_str()?)? },
        "add" => OpKind::Add,
        "mul" => OpKind::Mul,
        "addn" => {
            // n == 0 would make shape inference index an empty input list.
            let n = j.get("n")?.as_usize()?;
            anyhow::ensure!(
                (1..=MAX_NODE_INPUTS).contains(&n),
                "addn: n = {} outside 1..={}",
                n,
                MAX_NODE_INPUTS
            );
            OpKind::AddN { n }
        }
        "relu" => OpKind::Relu,
        "gelu" => OpKind::Gelu,
        "sigmoid" => OpKind::Sigmoid,
        "tanh" => OpKind::Tanh,
        "batchnorm" => OpKind::BatchNorm,
        "maxpool" => OpKind::MaxPool {
            k: dim_attr("k")?,
            stride: dim_attr("stride")?,
            pad: pad_parse(j.get("pad")?.as_str()?)?,
        },
        "avgpool" => OpKind::AvgPool {
            k: dim_attr("k")?,
            stride: dim_attr("stride")?,
            pad: pad_parse(j.get("pad")?.as_str()?)?,
        },
        "concat" => OpKind::Concat { axis: axis_attr("axis")? },
        "split" => {
            let parts = j.get("parts")?.as_usize()?;
            anyhow::ensure!(
                (1..=MAX_NODE_OUTS).contains(&parts),
                "split: parts = {} outside 1..={}",
                parts,
                MAX_NODE_OUTS
            );
            OpKind::Split { axis: axis_attr("axis")?, parts }
        }
        "reshape" => {
            let shape = j.get("shape")?.usize_array()?;
            // Checked product: shape inference multiplies these dims, which
            // must not overflow (debug) or wrap (release).
            checked_numel(&shape, "reshape target")?;
            OpKind::Reshape { shape }
        }
        "transpose" => {
            let perm = j.get("perm")?.usize_array()?;
            anyhow::ensure!(
                perm.len() <= MAX_RANK,
                "transpose: perm rank {} too large",
                perm.len()
            );
            OpKind::Transpose { perm }
        }
        "softmax" => OpKind::Softmax { axis: axis_attr("axis")? },
        "layernorm" => OpKind::LayerNorm,
        "fused_add_layernorm" => OpKind::FusedAddLayerNorm,
        "scale" => {
            let factor = j.get("factor")?.as_f64()?;
            anyhow::ensure!(factor.is_finite(), "scale: factor must be finite");
            OpKind::Scale { factor: factor as f32 }
        }
        "enlarge" => OpKind::Enlarge { kh: dim_attr("kh")?, kw: dim_attr("kw")? },
        "identity" => OpKind::Identity,
        _ => anyhow::bail!("unknown op '{}'", name),
    })
}

fn desc_to_json(t: &TensorDesc) -> Json {
    let mut j = Json::obj();
    j.set(
        "dtype",
        Json::Str(match t.dtype {
            DType::F32 => "f32".into(),
            DType::I32 => "i32".into(),
        }),
    );
    j.set("shape", Json::from_usizes(&t.shape));
    j
}

fn desc_from_json(j: &Json) -> anyhow::Result<TensorDesc> {
    let dtype = match j.get("dtype")?.as_str()? {
        "f32" => DType::F32,
        "i32" => DType::I32,
        d => anyhow::bail!("unknown dtype '{}'", d),
    };
    let shape = j.get("shape")?.usize_array()?;
    // Rank/element bounds before the descriptor can reach shape inference
    // or `n_elems` (whose products are unchecked on the trusted hot path).
    checked_numel(&shape, "tensor descriptor")?;
    Ok(TensorDesc { shape, dtype })
}

// ---------------------------------------------------------------------------
// Graph <-> JSON
// ---------------------------------------------------------------------------

pub fn export(g: &Graph, name: &str) -> anyhow::Result<Json> {
    let (dense, _) = g.compact()?;
    let nodes: Vec<Json> = dense
        .live_ids()
        .map(|id| {
            let n = dense.node(id);
            let mut j = op_to_json(&n.op);
            j.set(
                "inputs",
                Json::Arr(
                    n.inputs
                        .iter()
                        .map(|p| {
                            Json::Arr(vec![Json::Num(p.node.0 as f64), Json::Num(p.port as f64)])
                        })
                        .collect(),
                ),
            );
            j.set("outs", Json::Arr(n.outs.iter().map(desc_to_json).collect()));
            j
        })
        .collect();
    let mut m = Json::obj();
    m.set("ir_version", Json::Num(1.0));
    m.set("producer", Json::Str("rlflow".into()));
    m.set("graph_name", Json::Str(name.into()));
    m.set("nodes", Json::Arr(nodes));
    Ok(m)
}

pub fn import(m: &Json) -> anyhow::Result<Graph> {
    let mut g = Graph::new();
    let nodes = m.get("nodes")?.as_arr()?;
    anyhow::ensure!(
        nodes.len() <= MAX_NODES,
        "model declares {} nodes (limit {})",
        nodes.len(),
        MAX_NODES
    );
    for (i, nj) in nodes.iter().enumerate() {
        let op = op_from_json(nj)?;
        let outs_j = nj.get("outs")?.as_arr()?;
        anyhow::ensure!(
            outs_j.len() <= MAX_NODE_OUTS,
            "node {}: {} output descriptors (limit {})",
            i,
            outs_j.len(),
            MAX_NODE_OUTS
        );
        let outs: Vec<TensorDesc> =
            outs_j.iter().map(desc_from_json).collect::<anyhow::Result<_>>()?;
        match op {
            OpKind::Input | OpKind::Weight => {
                anyhow::ensure!(outs.len() == 1, "source node {} needs one descriptor", i);
                g.add_source(op, outs[0].clone());
            }
            _ => {
                let inputs_j = nj.get("inputs")?.as_arr()?;
                anyhow::ensure!(
                    inputs_j.len() <= MAX_NODE_INPUTS,
                    "node {}: fan-in {} (limit {})",
                    i,
                    inputs_j.len(),
                    MAX_NODE_INPUTS
                );
                let inputs: Vec<PortRef> = inputs_j
                    .iter()
                    .map(|p| {
                        let pair = p.as_arr()?;
                        anyhow::ensure!(pair.len() == 2, "input ref must be [node, port]");
                        let node = pair[0].as_usize()?;
                        anyhow::ensure!(node < i, "forward reference in node {}", i);
                        let port = pair[1].as_usize()?;
                        // `port` is stored as u16; an out-of-range value
                        // must error, not truncate onto a valid port.
                        anyhow::ensure!(port <= u16::MAX as usize, "port {} out of range", port);
                        Ok(PortRef { node: NodeId(node as u32), port: port as u16 })
                    })
                    .collect::<anyhow::Result<_>>()?;
                let id = g.add(op, &inputs)?;
                // Imported descriptors must agree with local shape inference:
                // catches corrupted or hand-edited files early.
                anyhow::ensure!(
                    g.node(id).outs == outs,
                    "node {}: stored shapes disagree with inference",
                    i
                );
            }
        }
    }
    g.validate()?;
    Ok(g)
}

pub fn save<P: AsRef<std::path::Path>>(g: &Graph, name: &str, path: P) -> anyhow::Result<()> {
    let model = export(g, name)?;
    std::fs::write(path, model.to_string_pretty())?;
    Ok(())
}

pub fn load<P: AsRef<std::path::Path>>(path: P) -> anyhow::Result<Graph> {
    let text = std::fs::read_to_string(path)?;
    import(&parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::canonical_hash;

    fn sample() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let _ = b.maxpool(c, 2, 2).unwrap();
        b.finish()
    }

    fn transformerish() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 8, 32]);
        let _ = b.transformer_encoder(x, 4, 2).unwrap();
        b.finish()
    }

    #[test]
    fn round_trip_preserves_hash() {
        for g in [sample(), transformerish()] {
            let model = export(&g, "t").unwrap();
            let g2 = import(&model).unwrap();
            assert_eq!(canonical_hash(&g), canonical_hash(&g2));
            assert_eq!(g.n_live(), g2.n_live());
        }
    }

    #[test]
    fn json_round_trip_via_disk() {
        let g = sample();
        let path = std::env::temp_dir().join("rlflow_onnx_test.json");
        save(&g, "t", &path).unwrap();
        let g2 = load(&path).unwrap();
        assert_eq!(canonical_hash(&g), canonical_hash(&g2));
    }

    #[test]
    fn all_ops_round_trip() {
        use OpKind::*;
        let ops = vec![
            Input,
            Weight,
            Conv2d { stride: 2, pad: PadMode::Valid, act: Activation::Relu },
            ConvBias { stride: 1, pad: PadMode::Same, act: Activation::None },
            MatMul { trans_a: true, trans_b: false, act: Activation::None },
            Linear { act: Activation::Gelu },
            Add,
            Mul,
            AddN { n: 4 },
            Relu,
            Gelu,
            Sigmoid,
            Tanh,
            BatchNorm,
            MaxPool { k: 3, stride: 2, pad: PadMode::Same },
            AvgPool { k: 2, stride: 2, pad: PadMode::Valid },
            Concat { axis: 1 },
            Split { axis: 2, parts: 3 },
            Reshape { shape: vec![2, 3, 4] },
            Transpose { perm: vec![1, 0] },
            Softmax { axis: 3 },
            LayerNorm,
            FusedAddLayerNorm,
            Scale { factor: 0.125 },
            Enlarge { kh: 5, kw: 5 },
            Identity,
        ];
        for op in ops {
            let j = op_to_json(&op);
            let back = op_from_json(&j).unwrap();
            assert_eq!(op, back, "round trip failed for {:?}", op);
        }
    }

    #[test]
    fn corrupted_shapes_rejected() {
        let g = sample();
        let mut model = export(&g, "t").unwrap();
        // Corrupt the last node's descriptor (an op node, since sources lead).
        if let Json::Obj(m) = &mut model {
            if let Some(Json::Arr(nodes)) = m.get_mut("nodes") {
                let last = nodes.len() - 1;
                if let Json::Obj(n) = &mut nodes[last] {
                    if let Some(Json::Arr(outs)) = n.get_mut("outs") {
                        if let Json::Obj(d) = &mut outs[0] {
                            d.insert("shape".into(), Json::from_usizes(&[9, 9, 9, 9]));
                        }
                    }
                }
            }
        }
        assert!(import(&model).is_err());
    }
}
