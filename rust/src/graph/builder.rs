//! Ergonomic construction layer used by the model zoo and rule patterns.
//!
//! Wraps a [`Graph`] with chainable helpers (`conv_bn_relu`, `linear`,
//! `attention`, ...) so the six evaluation models read like their paper
//! definitions. All helpers panic-free: errors propagate via `anyhow`.

use super::graph::{Graph, PortRef};
use super::op::{Activation, OpKind, PadMode};
use super::tensor::TensorDesc;

pub struct GraphBuilder {
    pub g: Graph,
}

impl Default for GraphBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self { g: Graph::new() }
    }

    pub fn finish(self) -> Graph {
        self.g
    }

    pub fn input(&mut self, shape: &[usize]) -> PortRef {
        PortRef::of(self.g.add_source(OpKind::Input, TensorDesc::f32(shape)))
    }

    pub fn weight(&mut self, shape: &[usize]) -> PortRef {
        PortRef::of(self.g.add_source(OpKind::Weight, TensorDesc::f32(shape)))
    }

    pub fn op(&mut self, op: OpKind, inputs: &[PortRef]) -> anyhow::Result<PortRef> {
        Ok(PortRef::of(self.g.add(op, inputs)?))
    }

    pub fn op_multi(&mut self, op: OpKind, inputs: &[PortRef]) -> anyhow::Result<Vec<PortRef>> {
        let id = self.g.add(op, inputs)?;
        let n = self.g.node(id).outs.len();
        Ok((0..n).map(|p| PortRef { node: id, port: p as u16 }).collect())
    }

    /// Convolution with a fresh weight of shape [co, ci, k, k].
    pub fn conv(
        &mut self,
        x: PortRef,
        co: usize,
        k: usize,
        stride: usize,
        pad: PadMode,
    ) -> anyhow::Result<PortRef> {
        let ci = self.channels(x)?;
        let w = self.weight(&[co, ci, k, k]);
        self.op(OpKind::Conv2d { stride, pad, act: Activation::None }, &[x, w])
    }

    /// conv -> batchnorm -> relu, the CNN zoo workhorse. BN kept as an
    /// explicit node so fusion substitutions have something to fuse.
    pub fn conv_bn_relu(
        &mut self,
        x: PortRef,
        co: usize,
        k: usize,
        stride: usize,
        pad: PadMode,
    ) -> anyhow::Result<PortRef> {
        let c = self.conv(x, co, k, stride, pad)?;
        let b = self.batchnorm(c)?;
        self.op(OpKind::Relu, &[b])
    }

    pub fn batchnorm(&mut self, x: PortRef) -> anyhow::Result<PortRef> {
        let c = self.channels(x)?;
        let scale = self.weight(&[c]);
        let shift = self.weight(&[c]);
        self.op(OpKind::BatchNorm, &[x, scale, shift])
    }

    /// Dense layer with fresh weight + bias: x @ W + b.
    pub fn linear(&mut self, x: PortRef, d_out: usize, act: Activation) -> anyhow::Result<PortRef> {
        let d_in = *self.shape(x)?.last().unwrap();
        let w = self.weight(&[d_in, d_out]);
        let b = self.weight(&[d_out]);
        self.op(OpKind::Linear { act }, &[x, w, b])
    }

    pub fn layernorm(&mut self, x: PortRef) -> anyhow::Result<PortRef> {
        let d = *self.shape(x)?.last().unwrap();
        let gamma = self.weight(&[d]);
        let beta = self.weight(&[d]);
        self.op(OpKind::LayerNorm, &[x, gamma, beta])
    }

    pub fn add(&mut self, a: PortRef, b: PortRef) -> anyhow::Result<PortRef> {
        self.op(OpKind::Add, &[a, b])
    }

    pub fn relu(&mut self, x: PortRef) -> anyhow::Result<PortRef> {
        self.op(OpKind::Relu, &[x])
    }

    pub fn gelu(&mut self, x: PortRef) -> anyhow::Result<PortRef> {
        self.op(OpKind::Gelu, &[x])
    }

    pub fn maxpool(&mut self, x: PortRef, k: usize, stride: usize) -> anyhow::Result<PortRef> {
        self.op(OpKind::MaxPool { k, stride, pad: PadMode::Same }, &[x])
    }

    pub fn avgpool(&mut self, x: PortRef, k: usize, stride: usize) -> anyhow::Result<PortRef> {
        self.op(OpKind::AvgPool { k, stride, pad: PadMode::Same }, &[x])
    }

    pub fn concat(&mut self, axis: usize, xs: &[PortRef]) -> anyhow::Result<PortRef> {
        self.op(OpKind::Concat { axis }, xs)
    }

    pub fn reshape(&mut self, x: PortRef, shape: &[usize]) -> anyhow::Result<PortRef> {
        self.op(OpKind::Reshape { shape: shape.to_vec() }, &[x])
    }

    pub fn transpose(&mut self, x: PortRef, perm: &[usize]) -> anyhow::Result<PortRef> {
        self.op(OpKind::Transpose { perm: perm.to_vec() }, &[x])
    }

    pub fn softmax(&mut self, x: PortRef, axis: usize) -> anyhow::Result<PortRef> {
        self.op(OpKind::Softmax { axis }, &[x])
    }

    /// Multi-head self-attention block over [B, S, D] built from primitive
    /// ops (separate Q/K/V projections, scaled dot-product, output proj) —
    /// exactly the structure RLFlow's transformer rules target (§4.10).
    pub fn self_attention(
        &mut self,
        x: PortRef,
        heads: usize,
    ) -> anyhow::Result<PortRef> {
        let shape = self.shape(x)?.clone();
        let (b, s, d) = (shape[0], shape[1], shape[2]);
        anyhow::ensure!(d % heads == 0, "attention: dims {} not divisible by heads {}", d, heads);
        let dh = d / heads;

        let q = self.linear(x, d, Activation::None)?;
        let k = self.linear(x, d, Activation::None)?;
        let v = self.linear(x, d, Activation::None)?;

        // [B,S,D] -> [B,H,S,dh]
        let split = |bld: &mut Self, t: PortRef| -> anyhow::Result<PortRef> {
            let r = bld.reshape(t, &[b, s, heads, dh])?;
            bld.transpose(r, &[0, 2, 1, 3])
        };
        let qh = split(self, q)?;
        let kh = split(self, k)?;
        let vh = split(self, v)?;

        let scores = self.op(
            OpKind::MatMul { trans_a: false, trans_b: true, act: Activation::None },
            &[qh, kh],
        )?; // [B,H,S,S]
        let scaled = self.op(
            OpKind::Scale { factor: 1.0 / (dh as f32).sqrt() },
            &[scores],
        )?;
        let probs = self.softmax(scaled, 3)?;
        let ctx = self.op(
            OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None },
            &[probs, vh],
        )?; // [B,H,S,dh]
        let merged = self.transpose(ctx, &[0, 2, 1, 3])?;
        let flat = self.reshape(merged, &[b, s, d])?;
        self.linear(flat, d, Activation::None)
    }

    /// Transformer encoder block (Fig. 11): MHA + residual add + layernorm,
    /// then FFN + residual add + layernorm. Post-LN variant as in BERT.
    pub fn transformer_encoder(
        &mut self,
        x: PortRef,
        heads: usize,
        ffn_mult: usize,
    ) -> anyhow::Result<PortRef> {
        let d = *self.shape(x)?.last().unwrap();
        let attn = self.self_attention(x, heads)?;
        let res1 = self.add(x, attn)?;
        let ln1 = self.layernorm(res1)?;
        let ff1 = self.linear(ln1, d * ffn_mult, Activation::Gelu)?;
        let ff2 = self.linear(ff1, d, Activation::None)?;
        let res2 = self.add(ln1, ff2)?;
        self.layernorm(res2)
    }

    // ---- introspection ------------------------------------------------------

    pub fn shape(&self, p: PortRef) -> anyhow::Result<&Vec<usize>> {
        Ok(&self.g.out_desc(p)?.shape)
    }

    fn channels(&self, x: PortRef) -> anyhow::Result<usize> {
        let s = self.shape(x)?;
        anyhow::ensure!(s.len() == 4, "expected NCHW, got rank {}", s.len());
        Ok(s[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_bn_relu_chain() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 32, 32]);
        let y = b.conv_bn_relu(x, 16, 3, 1, PadMode::Same).unwrap();
        assert_eq!(b.shape(y).unwrap(), &vec![1, 16, 32, 32]);
        b.finish().validate().unwrap();
    }

    #[test]
    fn attention_preserves_shape() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 16, 64]);
        let y = b.self_attention(x, 4).unwrap();
        assert_eq!(b.shape(y).unwrap(), &vec![2, 16, 64]);
        b.finish().validate().unwrap();
    }

    #[test]
    fn encoder_block_valid() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 8, 32]);
        let y = b.transformer_encoder(x, 4, 2).unwrap();
        assert_eq!(b.shape(y).unwrap(), &vec![1, 8, 32]);
        let g = b.finish();
        g.validate().unwrap();
        assert!(g.n_ops() > 15);
    }

    #[test]
    fn attention_rejects_bad_heads() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 8, 30]);
        assert!(b.self_attention(x, 4).is_err());
    }
}
