//! Tensor metadata: shape + dtype. All activations in the evaluation graphs
//! are f32; i32 exists for completeness of the ONNX-style serialisation.


#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
        }
    }
}

/// Shape + dtype of one tensor value flowing along a graph edge.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorDesc {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorDesc {
    pub fn f32(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), dtype: DType::F32 }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.n_elems() * self.dtype.size_bytes()
    }

    /// Numpy-style broadcast of two shapes; `None` if incompatible.
    pub fn broadcast(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
        let rank = a.len().max(b.len());
        let mut out = vec![0usize; rank];
        for i in 0..rank {
            let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
            let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
            out[i] = if da == db {
                da
            } else if da == 1 {
                db
            } else if db == 1 {
                da
            } else {
                return None;
            };
        }
        Some(out)
    }
}

impl std::fmt::Display for TensorDesc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let dims: Vec<String> = self.shape.iter().map(|d| d.to_string()).collect();
        write!(f, "{:?}[{}]", self.dtype, dims.join("x"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_count_and_bytes() {
        let t = TensorDesc::f32(&[2, 3, 4]);
        assert_eq!(t.n_elems(), 24);
        assert_eq!(t.bytes(), 96);
        assert_eq!(t.rank(), 3);
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(TensorDesc::broadcast(&[4, 1], &[3]), Some(vec![4, 3]));
        assert_eq!(TensorDesc::broadcast(&[2, 3], &[2, 3]), Some(vec![2, 3]));
        assert_eq!(TensorDesc::broadcast(&[5], &[2, 5]), Some(vec![2, 5]));
        assert_eq!(TensorDesc::broadcast(&[2, 3], &[4]), None);
        assert_eq!(TensorDesc::broadcast(&[], &[7]), Some(vec![7]));
    }
}
