//! Reward functions (§3.1.4, Eq. 2 and Eq. 3; Fig. 5's R1–R5).
//!
//! All runtime/memory deltas are normalised by the *initial* graph cost and
//! expressed in percent, so rewards are comparable across graphs of very
//! different absolute runtimes (BERT ~4 ms vs ResNet-50 ~26 ms in Table 2)
//! and the -100 invalid penalty keeps its intended magnitude.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RewardKind {
    /// Eq. 2 / Fig. 5 "R5": incremental runtime improvement
    /// `r_t = RT_{t-1} - RT_t`.
    Incremental,
    /// Fig. 5 "R2": improvement of the *new* runtime over the initial graph
    /// `r_t = RT_0 - RT_t`.
    NewRuntime,
    /// Eq. 3: `alpha (RT_{t-1} - RT_t) + beta (M_{t-1} - M_t)`.
    /// Fig. 5: R1 = tuned (0.8, 0.2); R3 = (0.1, 0.9); R4 = (0.5, 0.5).
    Combined { alpha: f32, beta: f32 },
}

impl RewardKind {
    /// Named presets matching Fig. 5's legend.
    pub fn preset(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "r1" => RewardKind::Combined { alpha: 0.8, beta: 0.2 },
            "r2" => RewardKind::NewRuntime,
            "r3" => RewardKind::Combined { alpha: 0.1, beta: 0.9 },
            "r4" => RewardKind::Combined { alpha: 0.5, beta: 0.5 },
            "r5" => RewardKind::Incremental,
            _ => anyhow::bail!("unknown reward preset '{}' (r1..r5)", name),
        })
    }

    pub fn label(&self) -> String {
        match self {
            RewardKind::Incremental => "incremental".into(),
            RewardKind::NewRuntime => "new_runtime".into(),
            RewardKind::Combined { alpha, beta } => format!("combined(a={alpha},b={beta})"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn compute(
        &self,
        rt_initial: f64,
        rt_prev: f64,
        rt_new: f64,
        mem_initial: f64,
        mem_prev: f64,
        mem_new: f64,
    ) -> f32 {
        let rt0 = rt_initial.max(1e-12);
        let m0 = mem_initial.max(1e-12);
        let d_rt = 100.0 * (rt_prev - rt_new) / rt0;
        let d_mem = 100.0 * (mem_prev - mem_new) / m0;
        let total_rt = 100.0 * (rt_initial - rt_new) / rt0;
        match self {
            RewardKind::Incremental => d_rt as f32,
            RewardKind::NewRuntime => total_rt as f32,
            RewardKind::Combined { alpha, beta } => (*alpha as f64 * d_rt + *beta as f64 * d_mem) as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incremental_is_stepwise_delta() {
        let r = RewardKind::Incremental.compute(10.0, 8.0, 6.0, 1.0, 1.0, 1.0);
        assert!((r - 20.0).abs() < 1e-5); // (8-6)/10 = 20%
    }

    #[test]
    fn new_runtime_is_total_improvement() {
        let r = RewardKind::NewRuntime.compute(10.0, 8.0, 6.0, 1.0, 1.0, 1.0);
        assert!((r - 40.0).abs() < 1e-5); // (10-6)/10 = 40%
    }

    #[test]
    fn combined_mixes_runtime_and_memory() {
        let k = RewardKind::Combined { alpha: 0.5, beta: 0.5 };
        let r = k.compute(10.0, 10.0, 8.0, 100.0, 100.0, 60.0);
        // 0.5*20% + 0.5*40% = 30%.
        assert!((r - 30.0).abs() < 1e-4);
    }

    #[test]
    fn regressions_are_negative() {
        let r = RewardKind::Incremental.compute(10.0, 8.0, 9.0, 1.0, 1.0, 1.0);
        assert!(r < 0.0);
    }

    #[test]
    fn presets_match_figure5() {
        assert_eq!(
            RewardKind::preset("r1").unwrap(),
            RewardKind::Combined { alpha: 0.8, beta: 0.2 }
        );
        assert_eq!(RewardKind::preset("r5").unwrap(), RewardKind::Incremental);
        assert!(RewardKind::preset("bogus").is_err());
    }
}
