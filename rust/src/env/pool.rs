//! Vectorised environment pool: B environments stepped in one batched
//! call across scoped worker threads.
//!
//! Layout follows the crate's worker-owns-its-model idiom
//! (`search::frontier`): the `RuleSet` is `Sync` and shared by reference;
//! each environment owns its [`EnvState`] plus a [`CostModel`] built from
//! one shared read-only memo snapshot ([`CostModel::from_snapshot`]) with
//! a small private overlay — the ROADMAP's shared-cache design. Per-env
//! RNG seeds and measurement-noise fields fork deterministically from the
//! pool seed (`coordinator::worker_seeds`), and every environment's
//! trajectory is a function of its own slot only, so results are
//! **bit-identical for any `threads` value** — pinned by
//! `tests/env_incremental.rs`.
//!
//! `step_batch` / `observe_batch` are what `coordinator::Pipeline` rollout
//! / eval and `experiments::suite` drive to collect B episodes per pass
//! instead of one.

use crate::cost::{CostModel, CostSnapshot};
use crate::graph::Graph;
use crate::util::Rng;
use crate::xfer::RuleSet;

use super::{Env, EnvConfig, EnvState, Observation, StepResult};

/// Shape of an [`EnvPool`]: batch width, per-env config, worker threads,
/// and the deterministic seed the per-env streams fork from.
#[derive(Debug, Clone)]
pub struct EnvPoolConfig {
    /// Number of environments (B).
    pub n_envs: usize,
    pub env: EnvConfig,
    /// Worker threads for batched calls (0 = all cores, capped at B).
    pub threads: usize,
    /// Root seed; per-env RNG/noise streams fork deterministically.
    pub seed: u64,
    /// Per-env measurement-noise std (0 = deterministic).
    pub noise_std: f64,
}

impl Default for EnvPoolConfig {
    fn default() -> Self {
        Self { n_envs: 1, env: EnvConfig::default(), threads: 0, seed: 0, noise_std: 0.0 }
    }
}

/// Domain separator: the measurement-noise field of an env must be
/// independent of its action stream even though both derive from the same
/// per-env seed.
const NOISE_STREAM: u64 = 0x9E3779B97F4A7C15;

struct EnvSlot {
    cost: CostModel,
    state: EnvState,
    rng: Rng,
}

impl EnvSlot {
    /// Rehydrate an [`Env`] around the slot's owned state, run `f`, and
    /// store the state back. Field-level borrows keep this allocation-free.
    fn with_env<R>(&mut self, rules: &RuleSet, f: impl FnOnce(&mut Env, &mut Rng) -> R) -> R {
        let state = std::mem::take(&mut self.state);
        let mut env = Env::from_state(rules, &self.cost, state);
        let r = f(&mut env, &mut self.rng);
        self.state = env.into_state();
        r
    }
}

/// B environments stepped as one batch across scoped worker threads (see
/// the module docs for the sharing layout and determinism contract).
pub struct EnvPool {
    rules: RuleSet,
    threads: usize,
    snapshot: CostSnapshot,
    slots: Vec<EnvSlot>,
}

impl EnvPool {
    /// Build B identical environments on `graph`. `base_cost` is costed
    /// once against the graph so the shared snapshot starts warm — every
    /// env then reads the frozen per-op costs lock-free.
    pub fn new(graph: &Graph, rules: RuleSet, base_cost: &CostModel, cfg: &EnvPoolConfig) -> Self {
        let n = cfg.n_envs.max(1);
        let _ = base_cost.graph_cost_fast(graph);
        let snapshot = base_cost.snapshot();
        let seeds = crate::coordinator::worker_seeds(cfg.seed, n);
        // One full match/cost pass builds a template the noise-free envs
        // clone — identical to constructing each from scratch (matching
        // and costing are deterministic), without B-1 redundant
        // O(rules x graph) passes. Noisy envs cost under their own per-env
        // noise field (different seeds, different initial runtimes), so
        // they construct individually.
        let template = if cfg.noise_std > 0.0 {
            None
        } else {
            let cost = CostModel::from_snapshot(&snapshot);
            Some(EnvState::new(graph.clone(), &rules, &cost, cfg.env.clone()))
        };
        let slots: Vec<EnvSlot> = seeds
            .into_iter()
            .map(|seed| {
                let mut cost = CostModel::from_snapshot(&snapshot);
                if cfg.noise_std > 0.0 {
                    cost = cost.with_noise(cfg.noise_std, seed ^ NOISE_STREAM);
                }
                let state = match &template {
                    Some(t) => t.clone(),
                    None => EnvState::new(graph.clone(), &rules, &cost, cfg.env.clone()),
                };
                EnvSlot { cost, state, rng: Rng::new(seed) }
            })
            .collect();
        Self { rules, threads: cfg.threads, snapshot, slots }
    }

    /// Batch width B.
    pub fn n_envs(&self) -> usize {
        self.slots.len()
    }

    /// The rule set every environment shares.
    pub fn rules(&self) -> &RuleSet {
        &self.rules
    }

    /// NO-OP action id, identical for every env.
    pub fn noop_action(&self) -> usize {
        self.rules.len()
    }

    /// The shared read-only cost snapshot the envs were built from.
    pub fn snapshot(&self) -> &CostSnapshot {
        &self.snapshot
    }

    /// Read-only view of env `i`'s owned state.
    pub fn state(&self, i: usize) -> &EnvState {
        &self.slots[i].state
    }

    /// Run `f(i, env, rng)` once per environment, fanned out over scoped
    /// worker threads in contiguous chunks. Each env's computation depends
    /// only on its own slot and `i`, so any thread count produces
    /// identical results.
    pub fn map_envs<R, F>(&mut self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &mut Env, &mut Rng) -> R + Sync,
    {
        let rules = &self.rules;
        let n = self.slots.len();
        let threads = crate::search::frontier::effective_threads(self.threads, n);
        if threads <= 1 {
            return self
                .slots
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| slot.with_env(rules, |env, rng| f(i, env, rng)))
                .collect();
        }
        let chunk = n.div_ceil(threads);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        std::thread::scope(|scope| {
            for (ci, (slots, outs)) in
                self.slots.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
            {
                let f = &f;
                scope.spawn(move || {
                    for (j, (slot, o)) in slots.iter_mut().zip(outs.iter_mut()).enumerate() {
                        let i = ci * chunk + j;
                        *o = Some(slot.with_env(rules, |env, rng| f(i, env, rng)));
                    }
                });
            }
        });
        out.into_iter().map(|o| o.expect("pool worker dropped a slot")).collect()
    }

    /// [`EnvPool::map_envs`] with per-env result streaming: `sink(i, r)`
    /// is invoked as each environment finishes instead of collecting a
    /// `Vec` — the async pipeline's collector pushes shard blocks into
    /// its bounded staging buffer this way, so a fast env's block is
    /// consumable while slow envs still run. `sink` may be called
    /// concurrently from different worker threads (once per env), and a
    /// blocking sink (e.g. a full bounded buffer) backpressures only the
    /// worker that produced the block. Results are identical to
    /// `map_envs` for any thread count; only delivery order varies.
    pub fn map_envs_streaming<R, F, S>(&mut self, f: F, sink: S)
    where
        R: Send,
        F: Fn(usize, &mut Env, &mut Rng) -> R + Sync,
        S: Fn(usize, R) + Sync,
    {
        let rules = &self.rules;
        let n = self.slots.len();
        let threads = crate::search::frontier::effective_threads(self.threads, n);
        if threads <= 1 {
            for (i, slot) in self.slots.iter_mut().enumerate() {
                let r = slot.with_env(rules, |env, rng| f(i, env, rng));
                sink(i, r);
            }
            return;
        }
        let chunk = n.div_ceil(threads);
        std::thread::scope(|scope| {
            for (ci, slots) in self.slots.chunks_mut(chunk).enumerate() {
                let f = &f;
                let sink = &sink;
                scope.spawn(move || {
                    for (j, slot) in slots.iter_mut().enumerate() {
                        let i = ci * chunk + j;
                        let r = slot.with_env(rules, |env, rng| f(i, env, rng));
                        sink(i, r);
                    }
                });
            }
        });
    }

    /// Run `f` on environment `i` alone (its own state and RNG stream).
    /// Because every env's trajectory is a function of its slot only,
    /// driving envs one at a time in any cross-env order reproduces the
    /// batched calls bit-for-bit — the sequential replay engine's
    /// collector runs on this.
    pub fn map_env_at<R>(&mut self, i: usize, f: impl FnOnce(&mut Env, &mut Rng) -> R) -> R {
        let slot = &mut self.slots[i];
        slot.with_env(&self.rules, f)
    }

    /// Step every environment with its action. `actions.len()` must be B.
    pub fn step_batch(&mut self, actions: &[(usize, usize)]) -> Vec<StepResult> {
        assert_eq!(actions.len(), self.slots.len(), "one action per env");
        self.map_envs(|i, env, _| env.step(actions[i]))
    }

    /// Step the subset of environments with a `Some` action (finished rows
    /// of an eval batch pass `None`).
    pub fn step_where(&mut self, actions: &[Option<(usize, usize)>]) -> Vec<Option<StepResult>> {
        assert_eq!(actions.len(), self.slots.len(), "one action slot per env");
        self.map_envs(|i, env, _| actions[i].map(|a| env.step(a)))
    }

    /// Observations for all environments (mask assembly only — cheap, so
    /// it stays on the calling thread).
    pub fn observe_batch(&self) -> Vec<Observation> {
        self.slots.iter().map(|s| s.state.observe()).collect()
    }

    /// Reset every environment to its initial graph (parallel: the reset
    /// re-derives each env's match lists from scratch).
    pub fn reset_all(&mut self) {
        self.map_envs(|_, env, _| env.reset());
    }

    /// Per-env RNG stream states, in slot order — the only cross-round
    /// collector state (episode collection resets the env per episode),
    /// captured at a round boundary for checkpointing.
    pub fn rng_states(&self) -> Vec<[u64; 4]> {
        self.slots.iter().map(|s| s.rng.state()).collect()
    }

    /// Restore per-env RNG streams captured with [`EnvPool::rng_states`];
    /// the pool continues every env's draw sequence exactly where the
    /// checkpointed run left it.
    pub fn restore_rng_states(&mut self, states: &[[u64; 4]]) -> anyhow::Result<()> {
        anyhow::ensure!(
            states.len() == self.slots.len(),
            "checkpoint has {} env RNG streams, pool has {} envs",
            states.len(),
            self.slots.len()
        );
        for (slot, s) in self.slots.iter_mut().zip(states) {
            slot.rng = Rng::from_state(*s);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::DeviceProfile;
    use crate::graph::{GraphBuilder, PadMode};
    use crate::xfer::library::standard_library;

    fn small_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv_bn_relu(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.maxpool(c, 2, 2).unwrap();
        b.finish()
    }

    fn pool_with(threads: usize, n_envs: usize) -> EnvPool {
        let cost = CostModel::new(DeviceProfile::rtx2070());
        EnvPool::new(
            &small_graph(),
            standard_library(),
            &cost,
            &EnvPoolConfig { n_envs, threads, seed: 7, ..Default::default() },
        )
    }

    /// Seeded random rollout through the pool API; returns per-env
    /// (reward, history) traces.
    fn rollout(pool: &mut EnvPool, steps: usize) -> Vec<(Vec<f32>, Vec<(usize, usize)>)> {
        let b = pool.n_envs();
        let mut traces: Vec<Vec<f32>> = vec![Vec::new(); b];
        for _ in 0..steps {
            let obs = pool.observe_batch();
            let actions: Vec<(usize, usize)> = (0..b)
                .map(|i| {
                    // Per-env deterministic pick: first valid xfer, loc 0.
                    (0..obs[i].xfer_mask.len() - 1)
                        .find(|&x| obs[i].xfer_mask[x])
                        .map(|x| (x, 0))
                        .unwrap_or((pool.noop_action(), 0))
                })
                .collect();
            let results = pool.step_batch(&actions);
            for (i, r) in results.iter().enumerate() {
                traces[i].push(r.reward);
            }
        }
        (0..b).map(|i| (traces[i].clone(), pool.state(i).history().to_vec())).collect()
    }

    #[test]
    fn pool_matches_single_env_stepping() {
        let mut pool = pool_with(2, 3);
        let out = rollout(&mut pool, 3);
        // A lone Env driven with the same policy must agree with row 0.
        let rules = standard_library();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mut env = Env::new(small_graph(), &rules, &cost, EnvConfig::default());
        let mut rewards = Vec::new();
        for _ in 0..3 {
            let obs = env.observe();
            let a = (0..rules.len())
                .find(|&x| obs.xfer_mask[x])
                .map(|x| (x, 0))
                .unwrap_or((env.noop_action(), 0));
            rewards.push(env.step(a).reward);
        }
        assert_eq!(out[0].0, rewards);
        assert_eq!(out[0].1, env.history().to_vec());
    }

    #[test]
    fn pool_deterministic_across_thread_counts() {
        let a = rollout(&mut pool_with(1, 4), 4);
        let b = rollout(&mut pool_with(3, 4), 4);
        let c = rollout(&mut pool_with(0, 4), 4);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn step_where_skips_none_rows() {
        let mut pool = pool_with(2, 3);
        let noop = pool.noop_action();
        let res = pool.step_where(&[Some((noop, 0)), None, Some((noop, 0))]);
        assert!(res[0].as_ref().unwrap().done);
        assert!(res[1].is_none());
        assert!(res[2].as_ref().unwrap().done);
        assert_eq!(pool.state(1).steps_taken(), 0, "None row must not step");
    }

    #[test]
    fn reset_all_restores_every_env() {
        let mut pool = pool_with(2, 3);
        let _ = rollout(&mut pool, 2);
        pool.reset_all();
        for i in 0..pool.n_envs() {
            assert_eq!(pool.state(i).steps_taken(), 0);
            assert!(pool.state(i).history().is_empty());
        }
    }

    #[test]
    fn rng_states_round_trip_and_length_check() {
        let mut pool = pool_with(1, 3);
        let states = pool.rng_states();
        let draws: Vec<u64> = (0..3).map(|i| pool.map_env_at(i, |_, rng| rng.next_u64())).collect();
        pool.restore_rng_states(&states).unwrap();
        let again: Vec<u64> = (0..3).map(|i| pool.map_env_at(i, |_, rng| rng.next_u64())).collect();
        assert_eq!(draws, again, "restored streams must continue identically");
        assert!(pool.restore_rng_states(&states[..2]).is_err(), "length mismatch must be typed");
    }

    #[test]
    fn noise_streams_are_per_env_deterministic() {
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mk = |threads| {
            EnvPool::new(
                &small_graph(),
                standard_library(),
                &cost,
                &EnvPoolConfig { n_envs: 3, threads, seed: 11, noise_std: 0.05, ..Default::default() },
            )
        };
        let a = rollout(&mut mk(1), 3);
        let b = rollout(&mut mk(3), 3);
        assert_eq!(a, b, "noisy pools must still be thread-count invariant");
        // Different seeds give different noise draws.
        let mut p1 = mk(1);
        let r1 = p1.state(0).runtime_ms();
        let r2 = p1.state(1).runtime_ms();
        assert_ne!(r1.to_bits(), r2.to_bits(), "per-env noise streams should differ");
    }
}
