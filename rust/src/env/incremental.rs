//! Incremental match maintenance for the environment step loop.
//!
//! The seed environment re-ran every `Rule::find` over the whole graph
//! after each applied substitution — O(rules × graph) per step, the
//! dominant cost of an RL rollout (X-RLflow makes the same observation).
//! [`MatchCache`] instead keeps the per-rule match lists and, after a
//! rewrite, consults the [`DirtyRegion`] of the [`ApplyReport`]:
//!
//!  * a cached location containing a dirty node may have died — the rule
//!    is re-found;
//!  * a *new* match must contain a live node whose local state the rewrite
//!    changed, so a rule is re-found when some live dirty node satisfies
//!    its [`Rule::op_relevant`] fingerprint;
//!  * every other rule's list is provably byte-identical to what a full
//!    refresh would produce (match validity and enumeration order are
//!    functions of per-node local state, which is unchanged outside the
//!    dirty region) and is kept as-is.
//!
//! Re-found rules run their ordinary full `find`, so the maintained lists
//! equal the full-refresh reference *exactly*, ordering included — pinned
//! by `tests/env_incremental.rs` over seeded random walks on the zoo.
//!
//! [`ApplyReport`]: crate::xfer::ApplyReport

use crate::graph::Graph;
use crate::xfer::{DirtyRegion, Location, RuleSet};

/// Counters for the maintenance decisions (exposed for benches/tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MatchStats {
    /// Rules whose `find` was re-run after a rewrite.
    pub refinds: u64,
    /// Rules whose cached list was provably unchanged and kept.
    pub keeps: u64,
}

/// Per-rule match lists maintained incrementally. Lists are stored *full*
/// (untruncated); observation masks cap them at `max_locs` so truncation
/// never loses matches across invalidations.
#[derive(Debug, Clone, Default)]
pub struct MatchCache {
    lists: Vec<Vec<Location>>,
    stats: MatchStats,
}

impl MatchCache {
    /// Full refresh: run every rule's `find` from scratch (construction,
    /// reset, and the `_reference` oracle path).
    pub fn full(rules: &RuleSet, g: &Graph) -> Self {
        let mut cache = Self::default();
        cache.refresh_full(rules, g);
        cache
    }

    /// Re-derive every list from scratch.
    pub fn refresh_full(&mut self, rules: &RuleSet, g: &Graph) {
        self.lists = rules.rules.iter().map(|r| r.find(g)).collect();
    }

    /// Patch the lists after one applied substitution: re-find exactly the
    /// rules whose patterns can intersect the dirty region, keep the rest.
    pub fn refresh(&mut self, rules: &RuleSet, after: &Graph, dirty: &DirtyRegion) {
        debug_assert_eq!(self.lists.len(), rules.len(), "cache/rule-set mismatch");
        for (list, rule) in self.lists.iter_mut().zip(rules.rules.iter()) {
            let gains = dirty.any_live(after, |op| rule.op_relevant(op));
            let losses =
                || list.iter().any(|loc| loc.iter().any(|&id| dirty.contains(id)));
            if gains || losses() {
                *list = rule.find(after);
                self.stats.refinds += 1;
            } else {
                self.stats.keeps += 1;
            }
        }
    }

    /// The maintained per-rule match lists (slot-indexed like the rule
    /// set; always equal to a from-scratch `Rule::find` pass).
    pub fn lists(&self) -> &[Vec<Location>] {
        &self.lists
    }

    /// Maintenance counters accumulated so far.
    pub fn stats(&self) -> MatchStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder, OpKind, PadMode};
    use crate::xfer::library::standard_library;
    use crate::xfer::apply_rule;

    /// Mixed conv + linear graph so some rule families are provably far
    /// from any conv-side rewrite.
    fn mixed_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        let y = b.input(&[2, 8]);
        let l = b.linear(y, 8, Activation::None).unwrap();
        let _ = b.op(OpKind::Tanh, &[l]).unwrap();
        b.finish()
    }

    #[test]
    fn refresh_equals_full_after_one_application() {
        let rules = standard_library();
        let g = mixed_graph();
        let mut cache = MatchCache::full(&rules, &g);
        let fuse = rules.index_of("fuse_conv_relu").unwrap();
        let loc = cache.lists()[fuse][0].clone();
        let mut g2 = g.clone();
        let report = apply_rule(&mut g2, rules.get(fuse).unwrap(), &loc).unwrap();
        let dirty = report.dirty_region(&g, &g2);
        cache.refresh(&rules, &g2, &dirty);
        let oracle = MatchCache::full(&rules, &g2);
        assert_eq!(cache.lists(), oracle.lists());
        // And the conv-side rewrite must not have re-found every rule:
        // e.g. the scale/reshape families cannot intersect the region.
        let stats = cache.stats();
        assert!(stats.keeps > 0, "no rule skipped: {stats:?}");
        assert!(stats.refinds > 0, "fusion must invalidate the conv rules");
    }
}
