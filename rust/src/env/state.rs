//! Graph -> tensor encoding: the `graph_tuple` half of the observation.
//!
//! The GNN artifacts consume three tensors per graph (shapes fixed at AOT
//! time, read from the manifest): node features `[N, F]`, adjacency
//! `[N, N]` and a node mask `[N]`. Only *op* nodes are encoded — sources
//! carry no information the op features (flops/bytes, which depend on the
//! weight shapes) do not already include. Graphs larger than `N` ops are
//! truncated in topological order (documented scaling decision, DESIGN.md).

use std::collections::HashMap;

use crate::cost::op_cost;
use crate::graph::{Graph, NodeId, OpKind, TensorDesc};

#[derive(Debug, Clone)]
pub struct EncodedGraph {
    /// Row-major `[n, f]`.
    pub feats: Vec<f32>,
    /// Row-major `[n, n]`, directed op->op edges.
    pub adj: Vec<f32>,
    /// `[n]`, 1.0 for live rows.
    pub mask: Vec<f32>,
    pub n: usize,
    pub f: usize,
}

pub struct StateEncoder {
    pub max_nodes: usize,
    pub n_feats: usize,
}

impl StateEncoder {
    pub fn new(max_nodes: usize, n_feats: usize) -> Self {
        assert!(n_feats >= crate::graph::op::N_OP_CLASSES + 10, "feature width too small");
        Self { max_nodes, n_feats }
    }

    pub fn encode(&self, g: &Graph) -> EncodedGraph {
        let (n, f) = (self.max_nodes, self.n_feats);
        let mut feats = vec![0.0f32; n * f];
        let mut adj = vec![0.0f32; n * n];
        let mut mask = vec![0.0f32; n];

        let order = match g.topo_order() {
            Ok(o) => o,
            Err(_) => return EncodedGraph { feats, adj, mask, n, f },
        };
        let ops: Vec<NodeId> = order
            .into_iter()
            .filter(|id| !matches!(g.node(*id).op, OpKind::Input | OpKind::Weight))
            .take(n)
            .collect();
        let row_of: HashMap<NodeId, usize> =
            ops.iter().enumerate().map(|(i, &id)| (id, i)).collect();

        let depths = g.depths_vec();
        let max_depth = depths.iter().copied().max().unwrap_or(1).max(1) as f32;
        let consumers = g.consumers_vec();
        let outputs: std::collections::HashSet<NodeId> = g.output_ids().into_iter().collect();

        for (row, &id) in ops.iter().enumerate() {
            mask[row] = 1.0;
            let node = g.node(id);
            let descs: Vec<&TensorDesc> = node
                .inputs
                .iter()
                .filter_map(|p| g.out_desc(*p).ok())
                .collect();
            let cost = op_cost(&node.op, &descs, &node.outs);
            let base = row * f;
            // One-hot op class.
            feats[base + node.op.class_index()] = 1.0;
            let k = crate::graph::op::N_OP_CLASSES;
            let out_elems: usize = node.outs.iter().map(|t| t.n_elems()).sum();
            feats[base + k] = ((cost.flops + 1.0).ln() / 20.0) as f32;
            feats[base + k + 1] = ((cost.bytes + 1.0).ln() / 20.0) as f32;
            feats[base + k + 2] = (out_elems as f32 + 1.0).ln() / 15.0;
            feats[base + k + 3] = depths[id.index()] as f32 / max_depth;
            feats[base + k + 4] = node.inputs.len() as f32 / 6.0;
            feats[base + k + 5] = consumers[id.index()].len() as f32 / 6.0;
            feats[base + k + 6] = if outputs.contains(&id) { 1.0 } else { 0.0 };
            feats[base + k + 7] = cost.launches as f32;
            feats[base + k + 8] = cost.efficiency as f32;
            feats[base + k + 9] = node.outs.len() as f32 / 4.0;

            // Directed edges from producing ops (weight/input edges dropped).
            for p in &node.inputs {
                if let Some(&src_row) = row_of.get(&p.node) {
                    adj[src_row * n + row] = 1.0;
                }
            }
        }
        EncodedGraph { feats, adj, mask, n, f }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, PadMode};

    fn enc() -> StateEncoder {
        StateEncoder::new(320, 32)
    }

    #[test]
    fn encode_small_graph() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        let g = b.finish();
        let e = enc().encode(&g);
        assert_eq!(e.mask.iter().sum::<f32>(), 2.0); // conv + relu
        // conv -> relu edge present.
        assert_eq!(e.adj[0 * 320 + 1], 1.0);
        // class one-hots valid.
        assert_eq!(e.feats[0 * 32 + crate::graph::OpKind::Relu.class_index()], 0.0);
    }

    #[test]
    fn encoding_masks_beyond_live_nodes() {
        let g = crate::zoo::squeezenet1_1();
        let e = enc().encode(&g);
        let live = e.mask.iter().filter(|&&m| m > 0.0).count();
        assert_eq!(live, g.n_ops());
        // Everything past the live rows is zero.
        for row in live..e.n {
            assert_eq!(e.mask[row], 0.0);
            assert!(e.feats[row * e.f..(row + 1) * e.f].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn zoo_graphs_fit_without_truncation() {
        for (info, g) in crate::zoo::all() {
            let e = enc().encode(&g);
            let live = e.mask.iter().filter(|&&m| m > 0.0).count();
            assert_eq!(live, g.n_ops(), "{} truncated", info.name);
        }
    }

    #[test]
    fn rewrite_changes_encoding() {
        let lib = crate::xfer::library::standard_library();
        let g = crate::zoo::bert_base();
        let e1 = enc().encode(&g);
        let rule = lib.get(lib.index_of("fuse_add_ln").unwrap()).unwrap();
        let mut g2 = g.clone();
        let loc = rule.find(&g2)[0].clone();
        crate::xfer::apply_rule(&mut g2, rule, &loc).unwrap();
        let e2 = enc().encode(&g2);
        assert_ne!(e1.feats, e2.feats);
    }

    #[test]
    fn adjacency_is_directed_and_acyclic_in_rows() {
        let g = crate::zoo::resnet18();
        let e = enc().encode(&g);
        // Topological encoding: all edges go from lower row to higher row.
        for src in 0..e.n {
            for dst in 0..e.n {
                if e.adj[src * e.n + dst] > 0.0 {
                    assert!(src < dst, "back edge {src}->{dst}");
                }
            }
        }
    }
}
