//! The Gym-style graph-optimisation environment (§3.1).
//!
//! `step((xfer_id, location))` applies one substitution and returns the
//! paper's 4-tuple: next state, reward, terminal flag and extra info. The
//! observation mirrors §3.1.3's `(graph_tuple, xfer_tuples, location_masks,
//! xfer_mask)`: a tensorised graph encoding for the GNN plus validity masks
//! for both action heads. `xfer_id == N_XFERS` is the NO-OP action that
//! terminates the episode (§3.1.3).

pub mod reward;
pub mod state;

pub use reward::RewardKind;
pub use state::{EncodedGraph, StateEncoder};

use crate::cost::CostModel;
use crate::graph::Graph;
use crate::xfer::{apply_rule, Location, RuleSet};

#[derive(Debug, Clone)]
pub struct EnvConfig {
    /// Hard cap on episode length.
    pub max_steps: usize,
    /// Reward for invalid actions (paper Eq. 2/3: -100).
    pub invalid_penalty: f32,
    pub reward: RewardKind,
    /// Per-xfer location limit (paper: 200).
    pub max_locs: usize,
}

impl Default for EnvConfig {
    fn default() -> Self {
        Self { max_steps: 60, invalid_penalty: -100.0, reward: RewardKind::Combined { alpha: 0.8, beta: 0.2 }, max_locs: 200 }
    }
}

/// Everything the agent observes about the current state.
#[derive(Debug, Clone)]
pub struct Observation {
    /// Valid transformations, length `n_xfers + 1` (NO-OP always valid).
    pub xfer_mask: Vec<bool>,
    /// Number of valid locations per xfer.
    pub location_counts: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct StepInfo {
    pub rule_name: Option<&'static str>,
    pub runtime_ms: f64,
    pub mem_bytes: f64,
    pub flops: f64,
    pub launches: u64,
    pub valid: bool,
}

#[derive(Debug, Clone)]
pub struct StepResult {
    pub reward: f32,
    pub done: bool,
    pub info: StepInfo,
}

pub struct Env<'a> {
    pub rules: &'a RuleSet,
    pub cost: &'a CostModel,
    pub cfg: EnvConfig,
    initial: Graph,
    pub graph: Graph,
    /// Per-rule match lists for the current graph (truncated to max_locs).
    locations: Vec<Vec<Location>>,
    steps: usize,
    rt_initial: f64,
    rt_prev: f64,
    mem_initial: f64,
    mem_prev: f64,
    /// Applied (xfer, location) history for the Fig. 10 heatmap.
    pub history: Vec<(usize, usize)>,
}

impl<'a> Env<'a> {
    pub fn new(graph: Graph, rules: &'a RuleSet, cost: &'a CostModel, cfg: EnvConfig) -> Self {
        let gc = cost.graph_cost_fast(&graph);
        let mut env = Self {
            rules,
            cost,
            cfg,
            initial: graph.clone(),
            graph,
            locations: Vec::new(),
            steps: 0,
            rt_initial: gc.runtime_ms,
            rt_prev: gc.runtime_ms,
            mem_initial: gc.mem_bytes,
            mem_prev: gc.mem_bytes,
            history: Vec::new(),
        };
        env.refresh_locations();
        env
    }

    /// NO-OP action id (== number of xfer slots).
    pub fn noop_action(&self) -> usize {
        self.rules.len()
    }

    pub fn reset(&mut self) {
        self.graph = self.initial.clone();
        self.steps = 0;
        self.rt_prev = self.rt_initial;
        self.mem_prev = self.mem_initial;
        self.history.clear();
        self.refresh_locations();
    }

    fn refresh_locations(&mut self) {
        self.locations = self
            .rules
            .rules
            .iter()
            .map(|r| {
                let mut locs = r.find(&self.graph);
                locs.truncate(self.cfg.max_locs);
                locs
            })
            .collect();
    }

    pub fn observe(&self) -> Observation {
        let mut xfer_mask: Vec<bool> = self.locations.iter().map(|l| !l.is_empty()).collect();
        xfer_mask.push(true); // NO-OP
        Observation {
            xfer_mask,
            location_counts: self.locations.iter().map(|l| l.len()).collect(),
        }
    }

    /// Xfer mask padded into a fixed `slots`-wide action space: rules at
    /// their slot index, NO-OP at the *last* slot, dead slots invalid.
    /// (The AOT artifacts reserve N_XFERS slots; the library may be smaller.)
    pub fn padded_xfer_mask(&self, slots: usize) -> Vec<f32> {
        let mut m = vec![0.0f32; slots];
        for (i, locs) in self.locations.iter().enumerate() {
            if i < slots - 1 && !locs.is_empty() {
                m[i] = 1.0;
            }
        }
        m[slots - 1] = 1.0; // NO-OP
        m
    }

    /// Location-validity mask (length max_locs) for one xfer.
    pub fn location_mask(&self, xfer: usize) -> Vec<bool> {
        let n = self.locations.get(xfer).map_or(0, |l| l.len());
        (0..self.cfg.max_locs).map(|i| i < n).collect()
    }

    pub fn runtime_ms(&self) -> f64 {
        self.rt_prev
    }

    pub fn initial_runtime_ms(&self) -> f64 {
        self.rt_initial
    }

    /// Relative runtime improvement so far, in percent.
    pub fn improvement_pct(&self) -> f64 {
        100.0 * (self.rt_initial - self.rt_prev) / self.rt_initial
    }

    pub fn steps_taken(&self) -> usize {
        self.steps
    }

    /// The paper's `step(action)`.
    pub fn step(&mut self, action: (usize, usize)) -> StepResult {
        let (xfer, loc) = action;
        self.steps += 1;
        let cap_hit = self.steps >= self.cfg.max_steps;

        // NO-OP terminates (§3.1.3).
        if xfer == self.noop_action() {
            return StepResult {
                reward: 0.0,
                done: true,
                info: self.info(None, true),
            };
        }

        let valid = xfer < self.rules.len() && loc < self.locations[xfer].len();
        if !valid {
            return StepResult {
                reward: self.cfg.invalid_penalty,
                done: cap_hit,
                info: self.info(None, false),
            };
        }

        let rule = self.rules.get(xfer).unwrap();
        let location = self.locations[xfer][loc].clone();
        let mut next = self.graph.clone();
        match apply_rule(&mut next, rule, &location) {
            Ok(_) => {
                let gc = self.cost.graph_cost_fast(&next);
                let reward = self.cfg.reward.compute(
                    self.rt_initial,
                    self.rt_prev,
                    gc.runtime_ms,
                    self.mem_initial,
                    self.mem_prev,
                    gc.mem_bytes,
                );
                self.graph = next;
                self.rt_prev = gc.runtime_ms;
                self.mem_prev = gc.mem_bytes;
                self.history.push((xfer, loc));
                self.refresh_locations();
                StepResult {
                    reward,
                    done: cap_hit,
                    info: self.info(Some(rule.name()), true),
                }
            }
            Err(_) => StepResult {
                reward: self.cfg.invalid_penalty,
                done: cap_hit,
                info: self.info(None, false),
            },
        }
    }

    fn info(&self, rule_name: Option<&'static str>, valid: bool) -> StepInfo {
        let gc = self.cost.graph_cost_fast(&self.graph);
        StepInfo {
            rule_name,
            runtime_ms: gc.runtime_ms,
            mem_bytes: gc.mem_bytes,
            flops: gc.flops,
            launches: gc.launches,
            valid,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::DeviceProfile;
    use crate::graph::{GraphBuilder, PadMode};
    use crate::xfer::library::standard_library;

    fn tiny_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        b.finish()
    }

    fn setup() -> (RuleSet, CostModel) {
        (standard_library(), CostModel::new(DeviceProfile::rtx2070()))
    }

    #[test]
    fn noop_terminates() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let noop = env.noop_action();
        let res = env.step((noop, 0));
        assert!(res.done);
        assert_eq!(res.reward, 0.0);
    }

    #[test]
    fn invalid_action_penalised() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let res = env.step((0, 199));
        assert_eq!(res.reward, -100.0);
        assert!(!res.done);
        assert!(!res.info.valid);
    }

    #[test]
    fn valid_fusion_gives_positive_reward() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let fuse = rules.index_of("fuse_conv_relu").unwrap();
        let obs = env.observe();
        assert!(obs.xfer_mask[fuse]);
        let res = env.step((fuse, 0));
        assert!(res.info.valid);
        assert!(res.reward > 0.0, "fusion reward {}", res.reward);
        assert!(env.improvement_pct() > 0.0);
    }

    #[test]
    fn mask_always_admits_noop() {
        let (rules, cost) = setup();
        let env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let obs = env.observe();
        assert_eq!(obs.xfer_mask.len(), rules.len() + 1);
        assert!(obs.xfer_mask[rules.len()]);
    }

    #[test]
    fn reset_restores_initial_state() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let fuse = rules.index_of("fuse_conv_relu").unwrap();
        env.step((fuse, 0));
        let rt_after = env.runtime_ms();
        env.reset();
        assert!(env.runtime_ms() > rt_after);
        assert_eq!(env.steps_taken(), 0);
        assert!(env.history.is_empty());
    }

    #[test]
    fn episode_caps_at_max_steps() {
        let (rules, cost) = setup();
        let cfg = EnvConfig { max_steps: 3, ..Default::default() };
        let mut env = Env::new(tiny_graph(), &rules, &cost, cfg);
        let mut done = false;
        for _ in 0..3 {
            done = env.step((0, 150)).done; // repeatedly invalid
        }
        assert!(done);
    }

    #[test]
    fn masks_reflect_matches() {
        let (rules, cost) = setup();
        let env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let fuse = rules.index_of("fuse_conv_relu").unwrap();
        let merge3 = rules.index_of("merge_linear3").unwrap();
        let obs = env.observe();
        assert!(obs.xfer_mask[fuse]);
        assert!(!obs.xfer_mask[merge3]);
        assert_eq!(obs.location_counts[fuse], 1);
        let lm = env.location_mask(fuse);
        assert!(lm[0]);
        assert!(!lm[1]);
    }

    #[test]
    fn bert_episode_random_walk_improves_or_neutral() {
        let (rules, cost) = setup();
        let mut env = Env::new(crate::zoo::bert_base(), &rules, &cost, EnvConfig::default());
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..5 {
            let obs = env.observe();
            let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
            let x = valid[rng.below(valid.len())];
            let l = rng.below(obs.location_counts[x]);
            let res = env.step((x, l));
            assert!(res.info.valid);
        }
        assert_eq!(env.history.len(), 5);
    }
}
