//! The Gym-style graph-optimisation environment (§3.1).
//!
//! `step((xfer_id, location))` applies one substitution and returns the
//! paper's 4-tuple: next state, reward, terminal flag and extra info. The
//! observation mirrors §3.1.3's `(graph_tuple, xfer_tuples, location_masks,
//! xfer_mask)`: a tensorised graph encoding for the GNN plus validity masks
//! for both action heads. `xfer_id == N_XFERS` is the NO-OP action that
//! terminates the episode (§3.1.3).
//!
//! The step loop is *incremental*: per-rule match lists are maintained in
//! place against the [`DirtyRegion`] of each applied substitution
//! ([`incremental::MatchCache`]) and the §3.1.4 reward is driven by
//! [`CostModel::delta_cost_fast`] off the same [`ApplyReport`] — one step
//! costs O(touched region), not O(graph). Setting
//! [`EnvConfig::full_refresh`] restores the original re-match-everything /
//! re-cost-everything behaviour as the `_reference` oracle the property
//! tests pin the incremental path against (bit-identical observations and
//! histories; rewards to 1e-9).
//!
//! [`ApplyReport`]: crate::xfer::ApplyReport
//! [`DirtyRegion`]: crate::xfer::DirtyRegion

pub mod incremental;
pub mod pool;
pub mod reward;
pub mod state;

pub use incremental::{MatchCache, MatchStats};
pub use pool::{EnvPool, EnvPoolConfig};
pub use reward::RewardKind;
pub use state::{EncodedGraph, StateEncoder};

use crate::cost::{CostModel, GraphCost};
use crate::graph::Graph;
use crate::xfer::{apply_rule, Location, RuleSet};

/// Knobs of one environment instance (episode shape, reward, incremental
/// vs full-refresh maintenance).
#[derive(Debug, Clone)]
pub struct EnvConfig {
    /// Hard cap on episode length.
    pub max_steps: usize,
    /// Reward for invalid actions (paper Eq. 2/3: -100).
    pub invalid_penalty: f32,
    pub reward: RewardKind,
    /// Per-xfer location limit (paper: 200).
    pub max_locs: usize,
    /// Disable incremental match/cost maintenance and re-derive everything
    /// from scratch each step — the `_reference` oracle for tests/benches.
    pub full_refresh: bool,
}

impl Default for EnvConfig {
    fn default() -> Self {
        Self {
            max_steps: 60,
            invalid_penalty: -100.0,
            reward: RewardKind::Combined { alpha: 0.8, beta: 0.2 },
            max_locs: 200,
            full_refresh: false,
        }
    }
}

/// Everything the agent observes about the current state.
#[derive(Debug, Clone)]
pub struct Observation {
    /// Valid transformations, length `n_xfers + 1` (NO-OP always valid).
    pub xfer_mask: Vec<bool>,
    /// Number of valid locations per xfer (capped at `max_locs`).
    pub location_counts: Vec<usize>,
}

/// The `info` half of the paper's step 4-tuple: the current graph's hot
/// costs plus what (if anything) was applied.
#[derive(Debug, Clone)]
pub struct StepInfo {
    /// Name of the applied rule (`None` for NO-OP/invalid steps).
    pub rule_name: Option<&'static str>,
    /// Estimated runtime of the current graph, in ms.
    pub runtime_ms: f64,
    /// Memory traffic of the current graph, in bytes.
    pub mem_bytes: f64,
    /// Floating-point operations of the current graph.
    pub flops: f64,
    /// Kernel launches of the current graph.
    pub launches: u64,
    /// The action applied successfully.
    pub valid: bool,
}

/// What one [`Env::step`] returned: reward, terminal flag, and step info.
#[derive(Debug, Clone)]
pub struct StepResult {
    /// The §3.1.4 reward (or the invalid penalty).
    pub reward: f32,
    /// The episode ended (NO-OP or step cap).
    pub done: bool,
    /// Cost/validity details of the step.
    pub info: StepInfo,
}

/// The owned, `Send` half of an environment: everything that mutates
/// during an episode. [`Env`] borrows the shared rule set and cost model
/// around it; [`EnvPool`] moves `EnvState`s across its worker threads
/// while sharing one `RuleSet` and giving each state its own `CostModel`.
#[derive(Clone, Default)]
pub struct EnvState {
    cfg: EnvConfig,
    initial: Graph,
    graph: Graph,
    /// Per-rule match lists for the current graph (full; observation masks
    /// truncate to `cfg.max_locs`).
    cache: MatchCache,
    steps: usize,
    rt_initial: f64,
    rt_prev: f64,
    mem_initial: f64,
    mem_prev: f64,
    /// Applied (xfer, location) history for the Fig. 10 heatmap.
    history: Vec<(usize, usize)>,
    /// Hot-field cost of `graph`, maintained incrementally.
    last_cost: GraphCost,
    initial_cost: GraphCost,
}

impl EnvState {
    /// Build a fresh episode state on `graph`: one full match pass + one
    /// full costing (everything later is maintained incrementally).
    pub fn new(graph: Graph, rules: &RuleSet, cost: &CostModel, cfg: EnvConfig) -> Self {
        let gc = cost.graph_cost_fast(&graph);
        Self {
            cfg,
            initial: graph.clone(),
            cache: MatchCache::full(rules, &graph),
            graph,
            steps: 0,
            rt_initial: gc.runtime_ms,
            rt_prev: gc.runtime_ms,
            mem_initial: gc.mem_bytes,
            mem_prev: gc.mem_bytes,
            history: Vec::new(),
            last_cost: gc,
            initial_cost: gc,
        }
    }

    /// The current graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Applied (xfer, location) actions so far (Fig. 10's heatmap data).
    pub fn history(&self) -> &[(usize, usize)] {
        &self.history
    }

    /// Steps taken this episode (valid, invalid and NO-OP alike).
    pub fn steps_taken(&self) -> usize {
        self.steps
    }

    /// Tracked runtime of the current graph, in ms.
    pub fn runtime_ms(&self) -> f64 {
        self.rt_prev
    }

    /// Runtime of the episode's initial graph, in ms.
    pub fn initial_runtime_ms(&self) -> f64 {
        self.rt_initial
    }

    /// Relative runtime improvement so far, in percent.
    pub fn improvement_pct(&self) -> f64 {
        100.0 * (self.rt_initial - self.rt_prev) / self.rt_initial
    }

    /// Match-maintenance counters (re-finds vs kept lists).
    pub fn match_stats(&self) -> MatchStats {
        self.cache.stats()
    }

    /// Assemble the §3.1.3 observation masks from the maintained lists.
    pub fn observe(&self) -> Observation {
        let lists = self.cache.lists();
        let mut xfer_mask: Vec<bool> = lists.iter().map(|l| !l.is_empty()).collect();
        xfer_mask.push(true); // NO-OP
        Observation {
            xfer_mask,
            location_counts: lists.iter().map(|l| l.len().min(self.cfg.max_locs)).collect(),
        }
    }

    /// Xfer mask padded into a fixed `slots`-wide action space: rules at
    /// their slot index, NO-OP at the *last* slot, dead slots invalid.
    /// (The AOT artifacts reserve N_XFERS slots; the library may be
    /// smaller.) A library *larger* than the slot space cannot be
    /// expressed — the overflow is saturated away explicitly, and debug
    /// builds assert on the misconfiguration instead of silently dropping
    /// valid rules.
    pub fn padded_xfer_mask(&self, slots: usize) -> Vec<f32> {
        let n_rules = self.cache.lists().len();
        debug_assert!(
            n_rules < slots,
            "xfer slot space ({slots}) cannot hold {n_rules} rules + NO-OP"
        );
        let mut m = vec![0.0f32; slots];
        let expressible = n_rules.min(slots.saturating_sub(1));
        for (i, locs) in self.cache.lists()[..expressible].iter().enumerate() {
            if !locs.is_empty() {
                m[i] = 1.0;
            }
        }
        m[slots - 1] = 1.0; // NO-OP
        m
    }

    /// Location-validity mask (length max_locs) for one xfer.
    pub fn location_mask(&self, xfer: usize) -> Vec<bool> {
        let n = self
            .cache
            .lists()
            .get(xfer)
            .map_or(0, |l| l.len().min(self.cfg.max_locs));
        (0..self.cfg.max_locs).map(|i| i < n).collect()
    }
}

/// The Gym-style environment (§3.1): the shared rule set + cost model,
/// borrowed around an owned [`EnvState`]. See the module docs for the
/// incremental step dataflow.
pub struct Env<'a> {
    /// The substitution vocabulary (slot indices = xfer actions).
    pub rules: &'a RuleSet,
    /// The cost model rewards are computed against.
    pub cost: &'a CostModel,
    state: EnvState,
}

impl<'a> Env<'a> {
    /// Build an environment with a fresh [`EnvState`] on `graph`.
    pub fn new(graph: Graph, rules: &'a RuleSet, cost: &'a CostModel, cfg: EnvConfig) -> Self {
        Self { rules, cost, state: EnvState::new(graph, rules, cost, cfg) }
    }

    /// Rehydrate an environment around a state produced by
    /// [`Env::into_state`] — no matching or costing is redone. The state
    /// must have been built against the same rule set (slot indices are
    /// positional).
    pub fn from_state(rules: &'a RuleSet, cost: &'a CostModel, state: EnvState) -> Self {
        debug_assert_eq!(state.cache.lists().len(), rules.len(), "state/rule-set mismatch");
        Self { rules, cost, state }
    }

    /// Surrender the owned state (for [`EnvPool`] worker hand-off).
    pub fn into_state(self) -> EnvState {
        self.state
    }

    /// Read-only view of the owned episode state.
    pub fn state(&self) -> &EnvState {
        &self.state
    }

    /// The current graph.
    pub fn graph(&self) -> &Graph {
        &self.state.graph
    }

    /// Applied (xfer, location) actions so far.
    pub fn history(&self) -> &[(usize, usize)] {
        &self.state.history
    }

    /// NO-OP action id (== number of xfer slots).
    pub fn noop_action(&self) -> usize {
        self.rules.len()
    }

    /// Restore the initial graph and re-derive the match lists from
    /// scratch (episode boundary).
    pub fn reset(&mut self) {
        let s = &mut self.state;
        s.graph = s.initial.clone();
        s.steps = 0;
        s.rt_prev = s.rt_initial;
        s.mem_prev = s.mem_initial;
        s.history.clear();
        s.last_cost = s.initial_cost;
        s.cache.refresh_full(self.rules, &s.graph);
    }

    /// The incremental per-rule match lists.
    pub fn match_lists(&self) -> &[Vec<Location>] {
        self.state.cache.lists()
    }

    /// Fresh full-refresh match lists — the `_reference` oracle the
    /// incremental maintenance is property-tested against.
    pub fn match_lists_reference(&self) -> Vec<Vec<Location>> {
        self.rules.rules.iter().map(|r| r.find(&self.state.graph)).collect()
    }

    /// The §3.1.3 observation masks (see [`EnvState::observe`]).
    pub fn observe(&self) -> Observation {
        self.state.observe()
    }

    /// Xfer mask padded into a fixed `slots`-wide action space (see
    /// [`EnvState::padded_xfer_mask`]).
    pub fn padded_xfer_mask(&self, slots: usize) -> Vec<f32> {
        self.state.padded_xfer_mask(slots)
    }

    /// Location-validity mask for one xfer.
    pub fn location_mask(&self, xfer: usize) -> Vec<bool> {
        self.state.location_mask(xfer)
    }

    /// Tracked runtime of the current graph, in ms.
    pub fn runtime_ms(&self) -> f64 {
        self.state.rt_prev
    }

    /// Runtime of the episode's initial graph, in ms.
    pub fn initial_runtime_ms(&self) -> f64 {
        self.state.rt_initial
    }

    /// Relative runtime improvement so far, in percent.
    pub fn improvement_pct(&self) -> f64 {
        self.state.improvement_pct()
    }

    /// Steps taken this episode.
    pub fn steps_taken(&self) -> usize {
        self.state.steps
    }

    /// The paper's `step(action)`.
    pub fn step(&mut self, action: (usize, usize)) -> StepResult {
        let (xfer, loc) = action;
        self.state.steps += 1;
        let cap_hit = self.state.steps >= self.state.cfg.max_steps;

        // NO-OP terminates (§3.1.3).
        if xfer == self.noop_action() {
            return StepResult { reward: 0.0, done: true, info: self.info(None, true) };
        }

        let avail = self
            .state
            .cache
            .lists()
            .get(xfer)
            .map_or(0, |l| l.len().min(self.state.cfg.max_locs));
        let valid = xfer < self.rules.len() && loc < avail;
        if !valid {
            return StepResult {
                reward: self.state.cfg.invalid_penalty,
                done: cap_hit,
                info: self.info(None, false),
            };
        }

        let rule = self.rules.get(xfer).unwrap();
        let location = self.state.cache.lists()[xfer][loc].clone();
        let mut next = self.state.graph.clone();
        match apply_rule(&mut next, rule, &location) {
            Ok(report) => {
                // Incremental reward costing: re-cost only what the rule
                // touched, off the cached parent cost. (The §3.1.4 noise
                // model is a stateless per-kernel field, so the delta
                // resamples only the touched nodes and agrees with the
                // full-recompute oracle to f64 summation order even with
                // noise enabled — no full-refresh fallback.)
                let gc = if self.state.cfg.full_refresh {
                    self.cost.graph_cost_fast(&next)
                } else {
                    self.cost.delta_cost_fast(&self.state.graph, &self.state.last_cost, &next, &report)
                };
                let reward = self.state.cfg.reward.compute(
                    self.state.rt_initial,
                    self.state.rt_prev,
                    gc.runtime_ms,
                    self.state.mem_initial,
                    self.state.mem_prev,
                    gc.mem_bytes,
                );
                if self.state.cfg.full_refresh {
                    self.state.graph = next;
                    self.state.cache.refresh_full(self.rules, &self.state.graph);
                } else {
                    // Incremental match maintenance: drop/re-find only the
                    // rules whose patterns can intersect the dirty region.
                    let dirty = report.dirty_region(&self.state.graph, &next);
                    self.state.graph = next;
                    self.state.cache.refresh(self.rules, &self.state.graph, &dirty);
                }
                self.state.rt_prev = gc.runtime_ms;
                self.state.mem_prev = gc.mem_bytes;
                self.state.last_cost = gc;
                self.state.history.push((xfer, loc));
                StepResult { reward, done: cap_hit, info: self.info(Some(rule.name()), true) }
            }
            Err(_) => StepResult {
                reward: self.state.cfg.invalid_penalty,
                done: cap_hit,
                info: self.info(None, false),
            },
        }
    }

    /// Step info off the cached cost of the current graph — invalid and
    /// NO-OP steps never trigger a recompute (the graph did not change).
    fn info(&self, rule_name: Option<&'static str>, valid: bool) -> StepInfo {
        let gc = &self.state.last_cost;
        StepInfo {
            rule_name,
            runtime_ms: gc.runtime_ms,
            mem_bytes: gc.mem_bytes,
            flops: gc.flops,
            launches: gc.launches,
            valid,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::DeviceProfile;
    use crate::graph::{GraphBuilder, PadMode};
    use crate::xfer::library::standard_library;

    fn tiny_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.relu(c).unwrap();
        b.finish()
    }

    fn setup() -> (RuleSet, CostModel) {
        (standard_library(), CostModel::new(DeviceProfile::rtx2070()))
    }

    #[test]
    fn noop_terminates() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let noop = env.noop_action();
        let res = env.step((noop, 0));
        assert!(res.done);
        assert_eq!(res.reward, 0.0);
    }

    #[test]
    fn invalid_action_penalised() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let res = env.step((0, 199));
        assert_eq!(res.reward, -100.0);
        assert!(!res.done);
        assert!(!res.info.valid);
    }

    #[test]
    fn invalid_and_noop_steps_reuse_cached_cost() {
        // Satellite fix: info() must come from the cached GraphCost, and
        // non-applying steps must not change it.
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let before = env.step((0, 199)).info;
        let again = env.step((0, 199)).info;
        assert_eq!(before.runtime_ms.to_bits(), again.runtime_ms.to_bits());
        assert_eq!(before.launches, again.launches);
        assert_eq!(before.runtime_ms.to_bits(), env.runtime_ms().to_bits());
    }

    #[test]
    fn valid_fusion_gives_positive_reward() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let fuse = rules.index_of("fuse_conv_relu").unwrap();
        let obs = env.observe();
        assert!(obs.xfer_mask[fuse]);
        let res = env.step((fuse, 0));
        assert!(res.info.valid);
        assert!(res.reward > 0.0, "fusion reward {}", res.reward);
        assert!(env.improvement_pct() > 0.0);
    }

    #[test]
    fn mask_always_admits_noop() {
        let (rules, cost) = setup();
        let env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let obs = env.observe();
        assert_eq!(obs.xfer_mask.len(), rules.len() + 1);
        assert!(obs.xfer_mask[rules.len()]);
    }

    #[test]
    fn reset_restores_initial_state() {
        let (rules, cost) = setup();
        let mut env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let fuse = rules.index_of("fuse_conv_relu").unwrap();
        env.step((fuse, 0));
        let rt_after = env.runtime_ms();
        env.reset();
        assert!(env.runtime_ms() > rt_after);
        assert_eq!(env.steps_taken(), 0);
        assert!(env.history().is_empty());
        assert_eq!(env.match_lists(), env.match_lists_reference());
    }

    #[test]
    fn episode_caps_at_max_steps() {
        let (rules, cost) = setup();
        let cfg = EnvConfig { max_steps: 3, ..Default::default() };
        let mut env = Env::new(tiny_graph(), &rules, &cost, cfg);
        let mut done = false;
        for _ in 0..3 {
            done = env.step((0, 150)).done; // repeatedly invalid
        }
        assert!(done);
    }

    #[test]
    fn masks_reflect_matches() {
        let (rules, cost) = setup();
        let env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let fuse = rules.index_of("fuse_conv_relu").unwrap();
        let merge3 = rules.index_of("merge_linear3").unwrap();
        let obs = env.observe();
        assert!(obs.xfer_mask[fuse]);
        assert!(!obs.xfer_mask[merge3]);
        assert_eq!(obs.location_counts[fuse], 1);
        let lm = env.location_mask(fuse);
        assert!(lm[0]);
        assert!(!lm[1]);
    }

    #[test]
    fn padded_mask_places_rules_and_noop() {
        // Satellite fix: exact-fit slot space (rules + NO-OP) keeps every
        // rule expressible, with the NO-OP pinned to the last slot.
        let (rules, cost) = setup();
        let env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        let slots = rules.len() + 1;
        let m = env.padded_xfer_mask(slots);
        assert_eq!(m.len(), slots);
        assert_eq!(m[slots - 1], 1.0);
        let obs = env.observe();
        for i in 0..rules.len() {
            assert_eq!(m[i] >= 0.5, obs.xfer_mask[i], "slot {i} mask drifted");
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "cannot hold")]
    fn padded_mask_overflow_asserts_in_debug() {
        let (rules, cost) = setup();
        let env = Env::new(tiny_graph(), &rules, &cost, EnvConfig::default());
        // Slot space smaller than the library: rules would be silently
        // inexpressible — debug builds must flag it.
        let _ = env.padded_xfer_mask(rules.len());
    }

    #[test]
    fn incremental_walk_matches_reference_oracle() {
        // Lockstep random walk: the incremental env and the full-refresh
        // reference must agree on observations, histories (bitwise) and
        // rewards/runtimes (1e-9). The heavyweight zoo-wide version lives
        // in tests/env_incremental.rs.
        let (rules, cost) = setup();
        let g = crate::zoo::squeezenet1_1();
        let mut inc = Env::new(g.clone(), &rules, &cost, EnvConfig::default());
        let mut reference =
            Env::new(g, &rules, &cost, EnvConfig { full_refresh: true, ..Default::default() });
        let mut rng = crate::util::Rng::new(0xE7E7);
        for _ in 0..8 {
            let obs = reference.observe();
            let inc_obs = inc.observe();
            assert_eq!(obs.xfer_mask, inc_obs.xfer_mask);
            assert_eq!(obs.location_counts, inc_obs.location_counts);
            assert_eq!(inc.match_lists(), inc.match_lists_reference());
            let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
            if valid.is_empty() {
                break;
            }
            let x = valid[rng.below(valid.len())];
            let l = rng.below(obs.location_counts[x]);
            let r_ref = reference.step((x, l));
            let r_inc = inc.step((x, l));
            assert_eq!(r_ref.done, r_inc.done);
            assert!((r_ref.reward - r_inc.reward).abs() < 1e-6);
            assert!((reference.runtime_ms() - inc.runtime_ms()).abs() < 1e-9);
            if r_ref.done {
                break;
            }
        }
        assert_eq!(reference.history(), inc.history());
        let stats = inc.state().match_stats();
        assert!(stats.keeps > 0, "incremental env never skipped a re-find");
    }

    #[test]
    fn bert_episode_random_walk_improves_or_neutral() {
        let (rules, cost) = setup();
        let mut env = Env::new(crate::zoo::bert_base(), &rules, &cost, EnvConfig::default());
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..5 {
            let obs = env.observe();
            let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
            let x = valid[rng.below(valid.len())];
            let l = rng.below(obs.location_counts[x]);
            let res = env.step((x, l));
            assert!(res.info.valid);
        }
        assert_eq!(env.history().len(), 5);
    }
}
