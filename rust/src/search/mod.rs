//! Search baselines the paper compares against (§4.4, Fig. 6/7).
//!
//! * [`greedy_optimise`] — TensorFlow-style rule application: repeatedly
//!   take the single best cost-*decreasing* substitution until none exists.
//! * [`taso_optimise`] — TASO's cost-based backtracking search, realised
//!   as a relaxed beam: at each depth every substitution of every frontier
//!   graph is tried; candidates below `alpha * best_cost` survive (the
//!   relaxation that lets the search take locally-worsening steps towards
//!   better optima), deduplicated by canonical hash, best `beam` kept.
//!
//! Both run over exactly the same rule set and cost model as the RL agent,
//! so Fig. 6 compares *search strategies*, not substitution vocabularies.

use std::collections::HashSet;
use std::time::Instant;

use crate::cost::CostModel;
use crate::graph::{canonical_hash, Graph};
use crate::xfer::{apply_rule, RuleSet};

#[derive(Debug, Clone)]
pub struct SearchLog {
    pub steps: Vec<(String, f64)>,
    pub initial_ms: f64,
    pub final_ms: f64,
    pub elapsed_s: f64,
    pub graphs_explored: usize,
}

impl SearchLog {
    pub fn improvement_pct(&self) -> f64 {
        100.0 * (self.initial_ms - self.final_ms) / self.initial_ms.max(1e-12)
    }
}

/// TF-style greedy optimisation.
pub fn greedy_optimise(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    max_steps: usize,
) -> (Graph, SearchLog) {
    let start = Instant::now();
    let initial_ms = cost.graph_runtime_ms(graph);
    let mut current = graph.clone();
    let mut current_ms = initial_ms;
    let mut log = Vec::new();
    let mut explored = 0;

    for _ in 0..max_steps {
        let mut best: Option<(Graph, f64, &'static str)> = None;
        for rule in &rules.rules {
            for loc in rule.find(&current) {
                let mut candidate = current.clone();
                if apply_rule(&mut candidate, rule.as_ref(), &loc).is_err() {
                    continue;
                }
                explored += 1;
                let ms = cost.graph_runtime_ms(&candidate);
                if ms < current_ms - 1e-12
                    && best.as_ref().map_or(true, |(_, b, _)| ms < *b)
                {
                    best = Some((candidate, ms, rule.name()));
                }
            }
        }
        match best {
            Some((g, ms, name)) => {
                current = g;
                current_ms = ms;
                log.push((name.to_string(), ms));
            }
            None => break,
        }
    }
    (
        current,
        SearchLog {
            steps: log,
            initial_ms,
            final_ms: current_ms,
            elapsed_s: start.elapsed().as_secs_f64(),
            graphs_explored: explored,
        },
    )
}

#[derive(Debug, Clone)]
pub struct TasoConfig {
    /// Relaxation factor: candidates with cost < alpha * best are kept.
    pub alpha: f64,
    /// Beam width (graphs carried between iterations).
    pub beam: usize,
    /// Maximum search depth (substitution-sequence length).
    pub depth: usize,
}

impl Default for TasoConfig {
    fn default() -> Self {
        Self { alpha: 1.05, beam: 4, depth: 80 }
    }
}

/// TASO-style cost-based backtracking search, realised as a relaxed beam:
/// at every depth, all substitutions of every frontier graph are applied;
/// candidates costing less than `alpha * best` survive (the relaxation that
/// lets the search take locally-worsening steps), deduplicated by canonical
/// hash, and the cheapest `beam` continue.
pub fn taso_optimise(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    cfg: &TasoConfig,
) -> (Graph, SearchLog) {
    let start = Instant::now();
    let initial_ms = cost.graph_runtime_ms(graph);
    let mut best_graph = graph.clone();
    let mut best_ms = initial_ms;
    let mut seen: HashSet<u64> = HashSet::new();
    seen.insert(canonical_hash(graph));

    let mut frontier: Vec<(f64, Graph)> = vec![(initial_ms, graph.clone())];
    let mut explored = 0;
    let mut log = Vec::new();
    let mut stale = 0usize;

    for _ in 0..cfg.depth {
        let mut candidates: Vec<(f64, Graph, &'static str)> = Vec::new();
        for (_, g) in &frontier {
            for rule in &rules.rules {
                for loc in rule.find(g) {
                    let mut candidate = g.clone();
                    if apply_rule(&mut candidate, rule.as_ref(), &loc).is_err() {
                        continue;
                    }
                    let h = canonical_hash(&candidate);
                    if !seen.insert(h) {
                        continue;
                    }
                    explored += 1;
                    let ms = cost.graph_runtime_ms(&candidate);
                    if ms < cfg.alpha * best_ms {
                        candidates.push((ms, candidate, rule.name()));
                    }
                }
            }
        }
        if candidates.is_empty() {
            break;
        }
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        candidates.truncate(cfg.beam);
        if candidates[0].0 < best_ms {
            best_ms = candidates[0].0;
            best_graph = candidates[0].1.clone();
            log.push((candidates[0].2.to_string(), best_ms));
            stale = 0;
        } else {
            // Within-alpha exploration that stops paying off terminates the
            // search (TASO's budget exhaustion analogue).
            stale += 1;
            if stale >= 6 {
                break;
            }
        }
        frontier = candidates.into_iter().map(|(ms, g, _)| (ms, g)).collect();
    }
    (
        best_graph,
        SearchLog {
            steps: log,
            initial_ms,
            final_ms: best_ms,
            elapsed_s: start.elapsed().as_secs_f64(),
            graphs_explored: explored,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::DeviceProfile;
    use crate::graph::{GraphBuilder, PadMode};
    use crate::xfer::library::standard_library;

    fn fixture() -> (Graph, RuleSet, CostModel) {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
        let c3 = b.conv(c2, 8, 1, 1, PadMode::Same).unwrap();
        let _ = b.relu(c3).unwrap();
        (
            b.finish(),
            standard_library(),
            CostModel::new(DeviceProfile::rtx2070()),
        )
    }

    #[test]
    fn greedy_strictly_improves() {
        let (g, rules, cost) = fixture();
        let (opt, log) = greedy_optimise(&g, &rules, &cost, 50);
        assert!(log.final_ms < log.initial_ms);
        assert!(log.improvement_pct() > 0.0);
        opt.validate().unwrap();
        // Log runtimes decrease monotonically.
        for w in log.steps.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
    }

    #[test]
    fn taso_at_least_matches_greedy() {
        let (g, rules, cost) = fixture();
        let (_, greedy_log) = greedy_optimise(&g, &rules, &cost, 50);
        let (opt, taso_log) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        assert!(
            taso_log.final_ms <= greedy_log.final_ms + 1e-9,
            "taso {} > greedy {}",
            taso_log.final_ms,
            greedy_log.final_ms
        );
        opt.validate().unwrap();
    }

    #[test]
    fn taso_respects_depth_bound() {
        let (g, rules, cost) = fixture();
        let cfg = TasoConfig { depth: 1, beam: 4, ..Default::default() };
        let (_, log) = taso_optimise(&g, &rules, &cost, &cfg);
        // One depth level: explored graphs bounded by first-level matches.
        assert!(log.graphs_explored <= rules.count_matches(&g));
        assert!(log.steps.len() <= 1);
    }

    #[test]
    fn optimised_graphs_semantically_equal() {
        let (g, rules, cost) = fixture();
        let (greedy_g, _) = greedy_optimise(&g, &rules, &cost, 20);
        assert!(crate::interp::semantically_equal(&g, &greedy_g, 2, 77, 2e-3).unwrap());
        let (taso_g, _) = taso_optimise(
            &g,
            &rules,
            &cost,
            &TasoConfig { depth: 4, beam: 4, ..Default::default() },
        );
        assert!(crate::interp::semantically_equal(&g, &taso_g, 2, 78, 2e-3).unwrap());
    }

    #[test]
    fn bert_transformer_fusions_found_by_greedy() {
        let g = crate::zoo::bert_base();
        let rules = standard_library();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let (_, log) = greedy_optimise(&g, &rules, &cost, 60);
        assert!(log.improvement_pct() > 0.5, "got {}%", log.improvement_pct());
        // The transformer fusion family must appear in the log.
        assert!(log.steps.iter().any(|(n, _)| n == "fuse_add_ln" || n == "merge_linear3"));
    }
}
