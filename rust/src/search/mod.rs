//! Search baselines the paper compares against (§4.4, Fig. 6/7).
//!
//! * [`greedy_optimise`] — TensorFlow-style rule application: repeatedly
//!   take the single best cost-*decreasing* substitution until none exists.
//! * [`taso_optimise`] — TASO's cost-based backtracking search, realised
//!   as a relaxed beam: at each depth every substitution of every frontier
//!   graph is tried; candidates below `alpha * best_cost` survive (the
//!   relaxation that lets the search take locally-worsening steps towards
//!   better optima), deduplicated by canonical hash, best `beam` kept.
//!
//! Both run over exactly the same rule set and cost model as the RL agent,
//! so Fig. 6 compares *search strategies*, not substitution vocabularies.
//!
//! # Engine
//!
//! Since the substitution frontier explodes combinatorially on transformer
//! graphs (X-RLflow), both baselines share one engine
//! ([`frontier::Frontier`]) with three ingredients:
//!
//! 1. **Location-level parallel expansion** — individual (frontier graph,
//!    rule, match location) sites fan out over `std::thread::scope`
//!    workers, each owning a [`CostModel`] built from a shared read-only
//!    snapshot. Sharding at site granularity (instead of (graph, rule)
//!    pairs) keeps one match-heavy rule from serialising a depth behind a
//!    single worker; per-entry match lists are maintained incrementally
//!    (`env::MatchCache` + `DirtyRegion`) so `Rule::find` never runs twice.
//! 2. **A transposition table** ([`frontier::TranspositionTable`]) keyed
//!    on [`canonical_hash`](crate::graph::canonical_hash) that persists
//!    across beam depths: a graph re-derived through a different
//!    substitution sequence is never re-costed, and TASO's explored-set
//!    dedup drops it before the graph is even retained.
//! 3. **Incremental costing** — fresh candidates are costed via
//!    `CostModel::delta_runtime_ms`, re-costing only the nodes the rule
//!    application touched; the full `graph_runtime_ms` recompute remains
//!    the oracle (reported `final_ms` always comes from it).
//!
//! # Cross-run memoisation
//!
//! [`memo::SearchCache`] persists results *across* search calls: a repeated
//! identical search (same config fingerprint, same root graph) is a pure
//! lookup, and the transposition table of every run seeds the next run's as
//! a read-only base layer. `experiments::ExperimentCtx` and the `rlflow`
//! CLI hold one cache across their whole lifetime ([`greedy_optimise_cached`]
//! / [`taso_optimise_cached`]; opt out with `--fresh-cache`).
//!
//! # Determinism
//!
//! Worker results are merged in canonical (frontier entry, rule, location)
//! enumeration order and every table update happens during that merge, so
//! results are **bit-identical for every thread count** — `threads: 1` *is*
//! the sequential reference (`tests/props.rs` pins this). Measurement noise
//! (`CostModel::noise_std > 0`) is a stateless per-kernel field, so noisy
//! searches parallelise, memoise and cache exactly like clean ones.
//!
//! The pre-engine implementations are kept verbatim as
//! [`greedy_optimise_reference`] / [`taso_optimise_reference`]: single
//! thread, no memoisation, a full cost recompute per candidate. They are
//! the semantic oracle for the property tests and the baseline bar for
//! `benches/fig7_opt_time.rs`.

pub mod frontier;
pub mod memo;

use std::time::Instant;

use crate::cost::CostModel;
use crate::graph::{canonical_hash, Graph};
use crate::xfer::{apply_rule, RuleSet};

pub use frontier::{Candidate, Frontier, FrontierEntry, TranspositionTable};
pub use memo::{CacheStats, SearchCache};

/// What one search run did: the applied-substitution trail plus the
/// counters the benches and experiment tables report.
#[derive(Debug, Clone)]
pub struct SearchLog {
    /// Applied substitutions as (rule name, runtime after application).
    pub steps: Vec<(String, f64)>,
    /// Runtime of the input graph (full recompute).
    pub initial_ms: f64,
    /// Runtime of the returned graph (full recompute).
    pub final_ms: f64,
    /// Wall-clock seconds the search (or cache lookup) took.
    pub elapsed_s: f64,
    /// Unique graphs costed by this run.
    pub graphs_explored: usize,
    /// Unique graphs in this run's transposition table when the search
    /// ended (cross-run base entries excluded).
    pub table_size: usize,
    /// Candidates answered by the table: cost-memo reuses (both layers)
    /// plus already-explored drops (TASO) — work the seed path would redo.
    pub memo_hits: usize,
    /// Worker threads candidate expansion ran with.
    pub threads: usize,
    /// The whole result came from a persistent [`SearchCache`] lookup.
    pub from_cache: bool,
}

impl SearchLog {
    /// Relative runtime improvement of the search, in percent.
    pub fn improvement_pct(&self) -> f64 {
        100.0 * (self.initial_ms - self.final_ms) / self.initial_ms.max(1e-12)
    }
}

/// TF-style greedy optimisation (parallel, memoised engine; auto threads).
pub fn greedy_optimise(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    max_steps: usize,
) -> (Graph, SearchLog) {
    greedy_optimise_threads(graph, rules, cost, max_steps, 0)
}

/// [`greedy_optimise`] with an explicit worker-thread count (0 = all
/// cores). Results are bit-identical for every `threads` value.
pub fn greedy_optimise_threads(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    max_steps: usize,
    threads: usize,
) -> (Graph, SearchLog) {
    greedy_engine(graph, rules, cost, max_steps, threads, None)
}

/// [`greedy_optimise_threads`] backed by a persistent [`SearchCache`]: a
/// repeated identical search is a pure lookup, and fresh runs seed / flush
/// the cache's cost memo for their config fingerprint.
pub fn greedy_optimise_cached(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    max_steps: usize,
    threads: usize,
    cache: &SearchCache,
) -> (Graph, SearchLog) {
    let fp = greedy_fingerprint(cost, rules, max_steps);
    if let Some(hit) = cache.lookup(fp, graph) {
        return hit;
    }
    let (g, log) = greedy_engine(graph, rules, cost, max_steps, threads, Some((cache, fp)));
    cache.store(fp, graph, &g, &log);
    (g, log)
}

/// The config fingerprint [`greedy_optimise_cached`] keys its cache
/// entries with — exposed so callers that ran an *uncached* search can
/// [`SearchCache::store`] its result under the right key.
pub fn greedy_fingerprint(cost: &CostModel, rules: &RuleSet, max_steps: usize) -> u64 {
    memo::config_fingerprint("greedy", &[max_steps as u64], cost, rules)
}

fn greedy_engine(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    max_steps: usize,
    threads: usize,
    memo: Option<(&SearchCache, u64)>,
) -> (Graph, SearchLog) {
    let start = Instant::now();
    let initial_ms = cost.graph_runtime_ms(graph);
    let threads = frontier::effective_threads(threads, usize::MAX);
    let mut front = Frontier::new(graph.clone(), initial_ms, rules);
    if let Some((cache, fp)) = memo {
        front.table.set_base(cache.cost_base(fp));
    }
    let mut current_ms = initial_ms;
    let mut log = Vec::new();
    let mut explored = 0usize;

    for _ in 0..max_steps {
        // Keep only candidates that strictly improve on the current graph,
        // and (best_only) retain at most one graph per worker stripe — the
        // argmin is all greedy needs. The table acts as a pure cost memo
        // here (greedy never drops re-derived candidates from
        // consideration).
        let cands = front.expand(rules, cost, current_ms - 1e-12, false, true, threads);
        let mut best: Option<Candidate> = None;
        for c in cands {
            explored += 1;
            front.table.hits += c.memo_hit as usize;
            front.table.insert(c.hash, c.ms);
            // Strict `<`: the earliest candidate in canonical order wins
            // ties, exactly as the sequential reference does.
            if c.graph.is_some() && best.as_ref().map_or(true, |b| c.ms < b.ms) {
                best = Some(c);
            }
        }
        match best {
            Some(c) => {
                log.push((c.rule_name.to_string(), c.ms));
                current_ms = c.ms;
                let entry = front.entry_from_candidate(rules, c);
                front.entries = vec![entry];
            }
            None => break,
        }
    }

    let final_graph = front.entries.swap_remove(0).graph;
    let final_ms = cost.graph_runtime_ms(&final_graph);
    if let Some((cache, fp)) = memo {
        cache.absorb_costs(fp, &front.table);
    }
    let slog = SearchLog {
        steps: log,
        initial_ms,
        final_ms,
        elapsed_s: start.elapsed().as_secs_f64(),
        graphs_explored: explored,
        table_size: front.table.len(),
        memo_hits: front.table.hits,
        threads,
        from_cache: false,
    };
    (final_graph, slog)
}

/// Knobs of the TASO-style relaxed beam search.
#[derive(Debug, Clone)]
pub struct TasoConfig {
    /// Relaxation factor: candidates with cost < alpha * best are kept.
    pub alpha: f64,
    /// Beam width (graphs carried between iterations).
    pub beam: usize,
    /// Maximum search depth (substitution-sequence length).
    pub depth: usize,
    /// Worker threads for candidate expansion; 0 = all available cores.
    /// Any value yields bit-identical results (1 = sequential reference).
    pub threads: usize,
}

impl Default for TasoConfig {
    fn default() -> Self {
        Self { alpha: 1.05, beam: 4, depth: 80, threads: 0 }
    }
}

/// TASO-style cost-based backtracking search, realised as a relaxed beam:
/// at every depth, all substitutions of every frontier graph are applied;
/// candidates costing less than `alpha * best` survive (the relaxation that
/// lets the search take locally-worsening steps), deduplicated by canonical
/// hash against every graph ever explored, and the cheapest `beam`
/// continue. Expansion runs on the parallel memoised engine (see module
/// docs); results are bit-identical for every `cfg.threads` value.
pub fn taso_optimise(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    cfg: &TasoConfig,
) -> (Graph, SearchLog) {
    taso_engine(graph, rules, cost, cfg, None)
}

/// [`taso_optimise`] backed by a persistent [`SearchCache`]. The cache's
/// cost memo seeds only the table's read-only layer — TASO's explored-set
/// dedup stays per-run, so seeding never *drops* candidates a cold run
/// would explore. Memoised candidate costs carry their first derivation's
/// f64 value (see [`TranspositionTable`]), so exact near-ties may resolve
/// differently warm vs fresh; repeated identical searches are bit-identical
/// via the result memo.
pub fn taso_optimise_cached(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    cfg: &TasoConfig,
    cache: &SearchCache,
) -> (Graph, SearchLog) {
    let fp = taso_fingerprint(cost, rules, cfg);
    if let Some(hit) = cache.lookup(fp, graph) {
        return hit;
    }
    let (g, log) = taso_engine(graph, rules, cost, cfg, Some((cache, fp)));
    cache.store(fp, graph, &g, &log);
    (g, log)
}

/// The config fingerprint [`taso_optimise_cached`] keys its cache entries
/// with — exposed so callers that ran an *uncached* search can
/// [`SearchCache::store`] its result under the right key.
pub fn taso_fingerprint(cost: &CostModel, rules: &RuleSet, cfg: &TasoConfig) -> u64 {
    memo::config_fingerprint(
        "taso",
        &[cfg.alpha.to_bits(), cfg.beam as u64, cfg.depth as u64],
        cost,
        rules,
    )
}

fn taso_engine(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    cfg: &TasoConfig,
    memo: Option<(&SearchCache, u64)>,
) -> (Graph, SearchLog) {
    let start = Instant::now();
    let initial_ms = cost.graph_runtime_ms(graph);
    let threads = frontier::effective_threads(cfg.threads, usize::MAX);
    let mut best_graph = graph.clone();
    let mut best_ms = initial_ms;
    let mut front = Frontier::new(graph.clone(), initial_ms, rules);
    if let Some((cache, fp)) = memo {
        front.table.set_base(cache.cost_base(fp));
    }
    let mut explored = 0usize;
    let mut log = Vec::new();
    let mut stale = 0usize;

    for _ in 0..cfg.depth {
        // `best_ms` is frozen for the whole depth, so the alpha filter can
        // run worker-side; `drop_seen` applies the explored-set dedup
        // against the frozen table snapshot there too.
        let cands = front.expand(rules, cost, cfg.alpha * best_ms, true, false, threads);
        let mut survivors: Vec<Candidate> = Vec::new();
        for c in cands {
            front.table.hits += c.memo_hit as usize;
            // In-depth duplicates (two workers deriving the same graph)
            // resolve here, in canonical order: first derivation counts.
            if !front.table.insert(c.hash, c.ms) {
                front.table.hits += 1;
                continue;
            }
            explored += 1;
            if c.graph.is_some() {
                survivors.push(c);
            }
        }
        if survivors.is_empty() {
            break;
        }
        survivors.sort_by(|a, b| a.ms.partial_cmp(&b.ms).unwrap_or(std::cmp::Ordering::Equal));
        survivors.truncate(cfg.beam);
        if survivors[0].ms < best_ms {
            best_ms = survivors[0].ms;
            best_graph = survivors[0].graph.clone().expect("survivors keep their graphs");
            log.push((survivors[0].rule_name.to_string(), best_ms));
            stale = 0;
        } else {
            // Within-alpha exploration that stops paying off terminates the
            // search (TASO's budget exhaustion analogue).
            stale += 1;
            if stale >= 6 {
                break;
            }
        }
        let next: Vec<FrontierEntry> = survivors
            .into_iter()
            .map(|c| front.entry_from_candidate(rules, c))
            .collect();
        front.entries = next;
    }

    let final_ms = cost.graph_runtime_ms(&best_graph);
    if let Some((cache, fp)) = memo {
        cache.absorb_costs(fp, &front.table);
    }
    let slog = SearchLog {
        steps: log,
        initial_ms,
        final_ms,
        elapsed_s: start.elapsed().as_secs_f64(),
        graphs_explored: explored,
        table_size: front.table.len(),
        memo_hits: front.table.hits,
        threads,
        from_cache: false,
    };
    (best_graph, slog)
}

// ---------------------------------------------------------------------------
// Reference implementations (the pre-engine seed path)
// ---------------------------------------------------------------------------

/// The original single-threaded greedy search: no memoisation, a full cost
/// recompute for every candidate. Kept verbatim as the semantic oracle for
/// the property tests and the baseline bar in `benches/fig7_opt_time.rs`.
pub fn greedy_optimise_reference(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    max_steps: usize,
) -> (Graph, SearchLog) {
    let start = Instant::now();
    let initial_ms = cost.graph_runtime_ms(graph);
    let mut current = graph.clone();
    let mut current_ms = initial_ms;
    let mut log = Vec::new();
    let mut explored = 0;

    for _ in 0..max_steps {
        let mut best: Option<(Graph, f64, &'static str)> = None;
        for rule in &rules.rules {
            for loc in rule.find(&current) {
                let mut candidate = current.clone();
                if apply_rule(&mut candidate, rule.as_ref(), &loc).is_err() {
                    continue;
                }
                explored += 1;
                let ms = cost.graph_runtime_ms(&candidate);
                if ms < current_ms - 1e-12
                    && best.as_ref().map_or(true, |(_, b, _)| ms < *b)
                {
                    best = Some((candidate, ms, rule.name()));
                }
            }
        }
        match best {
            Some((g, ms, name)) => {
                current = g;
                current_ms = ms;
                log.push((name.to_string(), ms));
            }
            None => break,
        }
    }
    (
        current,
        SearchLog {
            steps: log,
            initial_ms,
            final_ms: current_ms,
            elapsed_s: start.elapsed().as_secs_f64(),
            graphs_explored: explored,
            table_size: 0,
            memo_hits: 0,
            threads: 1,
            from_cache: false,
        },
    )
}

/// The original single-threaded TASO search: dedup within the run but no
/// cost memoisation and a full recompute per candidate. See
/// [`greedy_optimise_reference`] for why it is kept.
pub fn taso_optimise_reference(
    graph: &Graph,
    rules: &RuleSet,
    cost: &CostModel,
    cfg: &TasoConfig,
) -> (Graph, SearchLog) {
    let start = Instant::now();
    let initial_ms = cost.graph_runtime_ms(graph);
    let mut best_graph = graph.clone();
    let mut best_ms = initial_ms;
    let mut seen: std::collections::HashSet<u64> = std::collections::HashSet::new();
    seen.insert(canonical_hash(graph));

    let mut front: Vec<(f64, Graph)> = vec![(initial_ms, graph.clone())];
    let mut explored = 0;
    let mut log = Vec::new();
    let mut stale = 0usize;

    for _ in 0..cfg.depth {
        let mut candidates: Vec<(f64, Graph, &'static str)> = Vec::new();
        for (_, g) in &front {
            for rule in &rules.rules {
                for loc in rule.find(g) {
                    let mut candidate = g.clone();
                    if apply_rule(&mut candidate, rule.as_ref(), &loc).is_err() {
                        continue;
                    }
                    let h = canonical_hash(&candidate);
                    if !seen.insert(h) {
                        continue;
                    }
                    explored += 1;
                    let ms = cost.graph_runtime_ms(&candidate);
                    if ms < cfg.alpha * best_ms {
                        candidates.push((ms, candidate, rule.name()));
                    }
                }
            }
        }
        if candidates.is_empty() {
            break;
        }
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        candidates.truncate(cfg.beam);
        if candidates[0].0 < best_ms {
            best_ms = candidates[0].0;
            best_graph = candidates[0].1.clone();
            log.push((candidates[0].2.to_string(), best_ms));
            stale = 0;
        } else {
            stale += 1;
            if stale >= 6 {
                break;
            }
        }
        front = candidates.into_iter().map(|(ms, g, _)| (ms, g)).collect();
    }
    (
        best_graph,
        SearchLog {
            steps: log,
            initial_ms,
            final_ms: best_ms,
            elapsed_s: start.elapsed().as_secs_f64(),
            graphs_explored: explored,
            table_size: 0,
            memo_hits: 0,
            threads: 1,
            from_cache: false,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::DeviceProfile;
    use crate::graph::{GraphBuilder, PadMode};
    use crate::xfer::library::standard_library;

    fn fixture() -> (Graph, RuleSet, CostModel) {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c1 = b.conv_bn_relu(x, 8, 3, 1, PadMode::Same).unwrap();
        let c2 = b.conv(c1, 8, 1, 1, PadMode::Same).unwrap();
        let c3 = b.conv(c2, 8, 1, 1, PadMode::Same).unwrap();
        let _ = b.relu(c3).unwrap();
        (
            b.finish(),
            standard_library(),
            CostModel::new(DeviceProfile::rtx2070()),
        )
    }

    #[test]
    fn greedy_strictly_improves() {
        let (g, rules, cost) = fixture();
        let (opt, log) = greedy_optimise(&g, &rules, &cost, 50);
        assert!(log.final_ms < log.initial_ms);
        assert!(log.improvement_pct() > 0.0);
        opt.validate().unwrap();
        // Log runtimes decrease monotonically.
        for w in log.steps.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
    }

    #[test]
    fn taso_at_least_matches_greedy() {
        let (g, rules, cost) = fixture();
        let (_, greedy_log) = greedy_optimise(&g, &rules, &cost, 50);
        let (opt, taso_log) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        assert!(
            taso_log.final_ms <= greedy_log.final_ms + 1e-9,
            "taso {} > greedy {}",
            taso_log.final_ms,
            greedy_log.final_ms
        );
        opt.validate().unwrap();
    }

    #[test]
    fn taso_respects_depth_bound() {
        let (g, rules, cost) = fixture();
        let cfg = TasoConfig { depth: 1, beam: 4, ..Default::default() };
        let (_, log) = taso_optimise(&g, &rules, &cost, &cfg);
        // One depth level: explored graphs bounded by first-level matches.
        assert!(log.graphs_explored <= rules.count_matches(&g));
        assert!(log.steps.len() <= 1);
    }

    #[test]
    fn optimised_graphs_semantically_equal() {
        let (g, rules, cost) = fixture();
        let (greedy_g, _) = greedy_optimise(&g, &rules, &cost, 20);
        assert!(crate::interp::semantically_equal(&g, &greedy_g, 2, 77, 2e-3).unwrap());
        let (taso_g, _) = taso_optimise(
            &g,
            &rules,
            &cost,
            &TasoConfig { depth: 4, beam: 4, ..Default::default() },
        );
        assert!(crate::interp::semantically_equal(&g, &taso_g, 2, 78, 2e-3).unwrap());
    }

    #[test]
    fn bert_transformer_fusions_found_by_greedy() {
        let g = crate::zoo::bert_base();
        let rules = standard_library();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let (_, log) = greedy_optimise(&g, &rules, &cost, 60);
        assert!(log.improvement_pct() > 0.5, "got {}%", log.improvement_pct());
        // The transformer fusion family must appear in the log.
        assert!(log.steps.iter().any(|(n, _)| n == "fuse_add_ln" || n == "merge_linear3"));
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        // threads=1 IS the sequential reference; any other thread count
        // must reproduce it exactly — costs to the bit, graphs to the hash.
        let (g, rules, cost) = fixture();
        for threads in [2, 4] {
            let (sg, slog) =
                taso_optimise(&g, &rules, &cost, &TasoConfig { threads: 1, ..Default::default() });
            let (pg, plog) =
                taso_optimise(&g, &rules, &cost, &TasoConfig { threads, ..Default::default() });
            assert_eq!(slog.final_ms.to_bits(), plog.final_ms.to_bits());
            assert_eq!(canonical_hash(&sg), canonical_hash(&pg));
            assert_eq!(slog.graphs_explored, plog.graphs_explored);
            assert_eq!(slog.steps, plog.steps);

            let (sg, slog) = greedy_optimise_threads(&g, &rules, &cost, 50, 1);
            let (pg, plog) = greedy_optimise_threads(&g, &rules, &cost, 50, threads);
            assert_eq!(slog.final_ms.to_bits(), plog.final_ms.to_bits());
            assert_eq!(canonical_hash(&sg), canonical_hash(&pg));
            assert_eq!(slog.graphs_explored, plog.graphs_explored);
            assert_eq!(slog.steps, plog.steps);
        }
    }

    #[test]
    fn engine_agrees_with_reference_oracle() {
        // Memoisation + delta costing must not change what the search
        // finds on the fixture (near-ties may resolve differently, so the
        // pin is relative cost; bitwise equality is pinned against the
        // threads=1 run elsewhere).
        let (g, rules, cost) = fixture();
        let (_, log) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        let (_, rlog) = taso_optimise_reference(&g, &rules, &cost, &TasoConfig::default());
        let rel = (log.final_ms - rlog.final_ms).abs() / rlog.final_ms.max(1e-12);
        assert!(rel < 1e-6, "engine {} vs reference {}", log.final_ms, rlog.final_ms);
        let (_, log) = greedy_optimise(&g, &rules, &cost, 50);
        let (_, rlog) = greedy_optimise_reference(&g, &rules, &cost, 50);
        let rel = (log.final_ms - rlog.final_ms).abs() / rlog.final_ms.max(1e-12);
        assert!(rel < 1e-6, "greedy engine {} vs reference {}", log.final_ms, rlog.final_ms);
    }

    #[test]
    fn transposition_table_tracks_explored_graphs() {
        let (g, rules, cost) = fixture();
        let (_, log) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        // Every explored graph plus the seed is in the table, exactly once.
        assert_eq!(log.table_size, log.graphs_explored + 1);
        let (_, glog) = greedy_optimise(&g, &rules, &cost, 50);
        assert!(glog.table_size <= glog.graphs_explored + 1);
        assert!(glog.table_size > 0);
    }

    #[test]
    fn noisy_search_runs_parallel_and_matches_sequential() {
        // The per-kernel noise field is stateless, so noisy expansion no
        // longer needs the sequential downgrade the old stream-drawing
        // model forced: any thread count reproduces the sequential run to
        // the bit, noise included.
        let (g, rules, _) = fixture();
        let noisy = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 7);
        let (sg, slog) =
            taso_optimise(&g, &rules, &noisy, &TasoConfig { threads: 1, ..Default::default() });
        let (pg, plog) =
            taso_optimise(&g, &rules, &noisy, &TasoConfig { threads: 2, ..Default::default() });
        assert_eq!(plog.threads, 2, "noise must not force the sequential path");
        assert_eq!(slog.final_ms.to_bits(), plog.final_ms.to_bits());
        assert_eq!(canonical_hash(&sg), canonical_hash(&pg));
        assert_eq!(slog.graphs_explored, plog.graphs_explored);
        assert_eq!(slog.steps, plog.steps);
        // And the noise actually engaged: the clean run differs.
        let clean = CostModel::new(DeviceProfile::rtx2070());
        let (_, clog) = taso_optimise(&g, &rules, &clean, &TasoConfig::default());
        assert_ne!(clog.final_ms.to_bits(), plog.final_ms.to_bits());
    }

    #[test]
    fn cached_search_repeats_as_pure_lookup() {
        let (g, rules, cost) = fixture();
        let cache = SearchCache::new();
        let (g1, log1) = taso_optimise_cached(&g, &rules, &cost, &TasoConfig::default(), &cache);
        assert!(!log1.from_cache);
        let (g2, log2) = taso_optimise_cached(&g, &rules, &cost, &TasoConfig::default(), &cache);
        assert!(log2.from_cache, "second identical search must be a lookup");
        assert_eq!(log1.final_ms.to_bits(), log2.final_ms.to_bits());
        assert_eq!(canonical_hash(&g1), canonical_hash(&g2));
        assert_eq!(log1.steps, log2.steps);
        let stats = cache.stats();
        assert_eq!(stats.result_hits, 1);
        assert_eq!(stats.result_misses, 1);
        assert!(stats.cost_entries > 0, "the run's table must persist");
    }
}
