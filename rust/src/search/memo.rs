//! Cross-run search memoisation: the persistent [`SearchCache`].
//!
//! RLFlow's evaluation repeatedly optimises the same graph families under
//! varied configs (Fig. 6/7, Table 2/3), so the sequential-search cost the
//! paper inherits from TASO-style engines is amortisable *across* runs —
//! the ROADMAP's "persist the transposition table across the experiment
//! suite" item. The cache is shared by `experiments::ExperimentCtx` (one
//! per experiment process; every figure/table driver funnels its
//! deterministic baselines through it) and by the `rlflow` CLI via
//! [`global`] (opt out with `--fresh-cache`).
//!
//! Two layers, both keyed by a **config fingerprint** ([`config_fingerprint`]:
//! search method + parameters + cost-model fingerprint + rule vocabulary —
//! everything that determines results *except* the thread count, which is
//! bit-invariant by construction):
//!
//! 1. **Result memo** — `(fingerprint, canonical root hash)` → the final
//!    optimised graph and its [`SearchLog`]. A repeated identical search is
//!    a pure lookup: bit-identical graph and costs, `from_cache` set.
//! 2. **Cost shards** — per fingerprint, a frozen `hash → runtime` map that
//!    seeds the run's [`TranspositionTable`] *base layer*. The base is
//!    consulted only for cost lookups, never for TASO's explored-set dedup,
//!    so seeding never drops candidates a cold run would explore. Memoised
//!    candidate costs carry their *first derivation's* f64 value — the same
//!    first-derivation-canonical contract in-run memoisation already has —
//!    so they can differ from a fresh derivation's in the final ulps, and
//!    exact near-ties may resolve differently warm vs `--fresh-cache`
//!    (repeated identical searches stay bit-identical via the result memo;
//!    the engine-vs-oracle tests pin costs at 1e-6 relative for the same
//!    reason).
//!
//! Both layers are LRU-bounded; evictions are counted and surfaced through
//! [`SearchCache::stats`] together with hit/miss counters.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

use crate::cost::CostModel;
use crate::graph::{canonical_hash, Graph};
use crate::xfer::RuleSet;

use super::frontier::TranspositionTable;
use super::SearchLog;

/// Fingerprint of one search configuration: everything that determines the
/// search's results. `method` tags the algorithm ("greedy" / "taso"),
/// `params` its scalar knobs (beam, depth, alpha bits, step budgets...),
/// the cost model contributes device + noise, and the rule set its
/// vocabulary (names at their slot indices). Worker-thread counts are
/// deliberately excluded: results are bit-identical for every thread count.
pub fn config_fingerprint(method: &str, params: &[u64], cost: &CostModel, rules: &RuleSet) -> u64 {
    let mut h: u64 = 0x5EA2C4_CAC4E ^ 0xA5A5_5A5A_F0F0_0F0F;
    let mut fold = |v: u64| {
        h = (h ^ v)
            .rotate_left(27)
            .wrapping_mul(0x100000001B3)
            .wrapping_add(0x9E3779B97F4A7C15);
    };
    for b in method.bytes() {
        fold(b as u64);
    }
    fold(0xFF); // separator: "greedy"+[2] must not collide with "greedy2"+[]
    fold(params.len() as u64);
    for &p in params {
        fold(p);
    }
    fold(cost.fingerprint());
    fold(rules.fingerprint());
    h
}

/// Hit/miss/evict counters and current sizes of a [`SearchCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Identical searches answered entirely from the result memo.
    pub result_hits: u64,
    /// Lookups that fell through to a live search.
    pub result_misses: u64,
    /// Entries dropped by the LRU bounds (results and cost shards).
    pub evictions: u64,
    /// Memoised (fingerprint, root) search results currently held.
    pub result_entries: usize,
    /// Memoised graph costs currently held across all fingerprint shards.
    pub cost_entries: usize,
}

/// One canonical reporting line, shared by every surface that prints cache
/// stats (CLI, experiment drivers) so the format cannot drift.
impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} result hits / {} misses / {} evictions; {} results + {} graph costs held",
            self.result_hits,
            self.result_misses,
            self.evictions,
            self.result_entries,
            self.cost_entries
        )
    }
}

struct CachedResult {
    graph: Graph,
    log: SearchLog,
    last_used: u64,
}

#[derive(Default)]
struct CostShard {
    base: Arc<HashMap<u64, f64>>,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    results: HashMap<(u64, u64), CachedResult>,
    costs: HashMap<u64, CostShard>,
    tick: u64,
    result_hits: u64,
    result_misses: u64,
    evictions: u64,
}

/// Persistent, concurrently-usable search memo shared across search calls
/// (and, via [`global`], across every search a process runs). See the
/// module docs for the two layers and their soundness contracts. Interior
/// locking is an `RwLock` with short critical sections; the hot per-depth
/// path never touches it — a run takes one `Arc` of its cost shard up
/// front and flushes fresh entries back once at the end.
pub struct SearchCache {
    inner: RwLock<Inner>,
    max_results: usize,
    max_cost_entries: usize,
}

impl Default for SearchCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SearchCache {
    /// A cache with default bounds (512 results, ~1M memoised costs).
    pub fn new() -> Self {
        Self::with_capacity(512, 1 << 20)
    }

    /// A cache bounded to `max_results` memoised searches and
    /// `max_cost_entries` memoised graph costs (LRU eviction past either).
    pub fn with_capacity(max_results: usize, max_cost_entries: usize) -> Self {
        Self {
            inner: RwLock::new(Inner::default()),
            max_results: max_results.max(1),
            max_cost_entries: max_cost_entries.max(1),
        }
    }

    /// Look up a memoised search: the exact config (`fp`) on the exact root
    /// graph. On a hit the stored final graph and log are returned with
    /// `from_cache` set and `elapsed_s` re-stamped to the lookup time.
    pub fn lookup(&self, fp: u64, root: &Graph) -> Option<(Graph, SearchLog)> {
        self.lookup_hashed(fp, canonical_hash(root))
    }

    /// [`SearchCache::lookup`] for callers that already hold the root's
    /// canonical hash (the serve daemon keys requests, coalescing and disk
    /// persistence by `(fingerprint, root hash)` and never needs the root
    /// graph itself).
    pub fn lookup_hashed(&self, fp: u64, root_hash: u64) -> Option<(Graph, SearchLog)> {
        let t0 = Instant::now();
        let key = (fp, root_hash);
        let mut guard = self.inner.write().expect("search cache poisoned");
        guard.tick += 1;
        let tick = guard.tick;
        let inner = &mut *guard;
        match inner.results.get_mut(&key) {
            Some(hit) => {
                hit.last_used = tick;
                inner.result_hits += 1;
                let graph = hit.graph.clone();
                let mut log = hit.log.clone();
                log.from_cache = true;
                log.elapsed_s = t0.elapsed().as_secs_f64();
                Some((graph, log))
            }
            None => {
                inner.result_misses += 1;
                None
            }
        }
    }

    /// Memoise a finished search (`fp` on `root` produced `graph`/`log`).
    /// Evicts the least-recently-used result past the capacity bound.
    pub fn store(&self, fp: u64, root: &Graph, graph: &Graph, log: &SearchLog) {
        self.store_hashed(fp, canonical_hash(root), graph, log)
    }

    /// [`SearchCache::store`] keyed by a pre-computed root hash — the
    /// persistence replay path: entries reloaded from disk carry the root's
    /// hash, not the root graph. Counts neither a hit nor a miss.
    pub fn store_hashed(&self, fp: u64, root_hash: u64, graph: &Graph, log: &SearchLog) {
        let key = (fp, root_hash);
        let mut guard = self.inner.write().expect("search cache poisoned");
        guard.tick += 1;
        let tick = guard.tick;
        let inner = &mut *guard;
        let mut log = log.clone();
        log.from_cache = false;
        inner
            .results
            .insert(key, CachedResult { graph: graph.clone(), log, last_used: tick });
        while inner.results.len() > self.max_results {
            let Some((&lru, _)) = inner.results.iter().min_by_key(|(_, v)| v.last_used) else {
                break;
            };
            inner.results.remove(&lru);
            inner.evictions += 1;
        }
    }

    /// Clone out every memoised result as `(fingerprint, root hash, graph,
    /// log)`, sorted by key so a snapshot of a fixed cache state always
    /// serialises to identical bytes. This is the compaction source for the
    /// serve daemon's disk persistence; logs come back with `from_cache`
    /// cleared, exactly as [`SearchCache::store_hashed`] will re-store them.
    pub fn snapshot_results(&self) -> Vec<(u64, u64, Graph, SearchLog)> {
        let inner = self.inner.read().expect("search cache poisoned");
        let mut out: Vec<(u64, u64, Graph, SearchLog)> = inner
            .results
            .iter()
            .map(|(&(fp, root), r)| (fp, root, r.graph.clone(), r.log.clone()))
            .collect();
        out.sort_by_key(|&(fp, root, _, _)| (fp, root));
        out
    }

    /// The frozen cost map memoised for `fp` (empty for a cold fingerprint)
    /// — installed as the run's [`TranspositionTable`] base layer.
    pub fn cost_base(&self, fp: u64) -> Arc<HashMap<u64, f64>> {
        let mut guard = self.inner.write().expect("search cache poisoned");
        guard.tick += 1;
        let tick = guard.tick;
        match guard.costs.get_mut(&fp) {
            Some(shard) => {
                shard.last_used = tick;
                Arc::clone(&shard.base)
            }
            None => Arc::default(),
        }
    }

    /// Fold a finished run's freshly-costed graphs back into `fp`'s shard.
    /// Entries already memoised keep their stored value (first derivation
    /// stays canonical across the cache lifetime); LRU shards are evicted
    /// while the global cost bound is exceeded.
    pub fn absorb_costs(&self, fp: u64, table: &TranspositionTable) {
        if table.is_empty() {
            return;
        }
        let mut guard = self.inner.write().expect("search cache poisoned");
        guard.tick += 1;
        let tick = guard.tick;
        let inner = &mut *guard;
        let shard = inner.costs.entry(fp).or_default();
        // Only genuinely-new keys force the copy-on-write merge; a run that
        // rediscovered nothing just bumps the shard's LRU stamp (repeated
        // near-identical runs must not pay O(shard) each time).
        let fresh: Vec<(u64, f64)> =
            table.local_entries().filter(|(k, _)| !shard.base.contains_key(k)).collect();
        if !fresh.is_empty() {
            let mut merged = (*shard.base).clone();
            for (k, v) in fresh {
                merged.insert(k, v);
            }
            shard.base = Arc::new(merged);
        }
        shard.last_used = tick;
        let mut total: usize = inner.costs.values().map(|s| s.base.len()).sum();
        while total > self.max_cost_entries && inner.costs.len() > 1 {
            let Some((&lru, _)) = inner.costs.iter().min_by_key(|(_, s)| s.last_used) else {
                break;
            };
            total -= inner.costs.remove(&lru).map_or(0, |s| s.base.len());
            inner.evictions += 1;
        }
        if total > self.max_cost_entries {
            // A single shard larger than the whole budget: drop it.
            inner.costs.clear();
            inner.evictions += 1;
        }
    }

    /// Current counters and sizes.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.read().expect("search cache poisoned");
        CacheStats {
            result_hits: inner.result_hits,
            result_misses: inner.result_misses,
            evictions: inner.evictions,
            result_entries: inner.results.len(),
            cost_entries: inner.costs.values().map(|s| s.base.len()).sum(),
        }
    }

    /// Drop every memoised entry (counters are kept).
    pub fn clear(&self) {
        let mut inner = self.inner.write().expect("search cache poisoned");
        inner.results.clear();
        inner.costs.clear();
    }
}

static GLOBAL: OnceLock<Arc<SearchCache>> = OnceLock::new();

/// The process-wide cache the CLI holds across `optimize`/`experiment`
/// invocations within one process (`--fresh-cache` opts out by building a
/// private one instead).
pub fn global() -> Arc<SearchCache> {
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(SearchCache::new())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::DeviceProfile;
    use crate::xfer::library::standard_library;

    #[test]
    fn fingerprint_separates_methods_params_and_noise() {
        let rules = standard_library();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let fp = |m: &str, p: &[u64], c: &CostModel| config_fingerprint(m, p, c, &rules);
        assert_ne!(fp("greedy", &[60], &cost), fp("taso", &[60], &cost));
        assert_ne!(fp("greedy", &[60], &cost), fp("greedy", &[50], &cost));
        let noisy = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 1);
        assert_ne!(fp("greedy", &[60], &cost), fp("greedy", &[60], &noisy));
        let other_seed = CostModel::new(DeviceProfile::rtx2070()).with_noise(0.05, 2);
        assert_ne!(fp("greedy", &[60], &noisy), fp("greedy", &[60], &other_seed));
        // Stable across calls.
        assert_eq!(fp("taso", &[4, 80], &cost), fp("taso", &[4, 80], &cost));
    }

    #[test]
    fn hashed_api_matches_graph_api() {
        let cache = SearchCache::new();
        let mut b = crate::graph::GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let _ = b.relu(x).unwrap();
        let g = b.finish();
        let h = crate::graph::canonical_hash(&g);
        let log = SearchLog {
            steps: vec![("r".into(), 1.5)],
            initial_ms: 2.0,
            final_ms: 1.5,
            elapsed_s: 0.1,
            graphs_explored: 3,
            table_size: 4,
            memo_hits: 1,
            threads: 2,
            from_cache: false,
        };
        cache.store_hashed(7, h, &g, &log);
        // The graph-keyed lookup finds the hash-keyed store and vice versa.
        let (g1, l1) = cache.lookup(7, &g).expect("hash-keyed store must hit");
        assert!(l1.from_cache);
        assert_eq!(crate::graph::canonical_hash(&g1), h);
        assert_eq!(l1.steps, log.steps);
        let (_, l2) = cache.lookup_hashed(7, h).expect("graph hash must hit");
        assert_eq!(l2.final_ms.to_bits(), log.final_ms.to_bits());
        // Snapshot comes back sorted, with from_cache cleared.
        cache.store_hashed(3, h, &g, &log);
        let snap = cache.snapshot_results();
        assert_eq!(snap.len(), 2);
        assert!(snap[0].0 < snap[1].0, "snapshot must be key-sorted");
        assert!(snap.iter().all(|(_, _, _, l)| !l.from_cache));
    }

    #[test]
    fn lru_bounds_hold() {
        let cache = SearchCache::with_capacity(2, 1 << 20);
        let mut b = crate::graph::GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let _ = b.relu(x).unwrap();
        let g = b.finish();
        let log = SearchLog {
            steps: vec![],
            initial_ms: 1.0,
            final_ms: 1.0,
            elapsed_s: 0.0,
            graphs_explored: 0,
            table_size: 0,
            memo_hits: 0,
            threads: 1,
            from_cache: false,
        };
        for fp in 0..3u64 {
            cache.store(fp, &g, &g, &log);
        }
        let s = cache.stats();
        assert_eq!(s.result_entries, 2, "LRU bound must hold");
        assert_eq!(s.evictions, 1);
        // The oldest fingerprint was evicted; the two youngest remain.
        assert!(cache.lookup(0, &g).is_none());
        assert!(cache.lookup(2, &g).is_some());
    }
}
