//! Shared frontier machinery behind both deterministic search baselines.
//!
//! [`Frontier`] owns the graphs alive at the current search depth plus the
//! cross-depth [`TranspositionTable`]; [`Frontier::expand`] is the one
//! candidate-generation path both `greedy_optimise` and `taso_optimise`
//! call. Expansion fans (frontier graph, rule) pairs out across scoped
//! worker threads — the same worker-owns-its-model pattern as
//! `env::EnvPool`: the `RuleSet` is `Sync` and is shared by reference,
//! while each worker owns a [`CostModel`] built from the parent's shared
//! read-only memo snapshot plus a small private overlay (interior
//! mutability makes the cost model deliberately `!Sync`).
//!
//! Determinism: workers take pairs round-robin but results are merged back
//! in canonical (frontier entry, rule, location) enumeration order, and all
//! transposition-table updates happen on the caller's thread during that
//! merge. The candidate stream is therefore *bit-identical* for every
//! thread count, which the search property tests pin down.
//!
//! Costing: a candidate already in the table reuses the memoised runtime
//! (re-derived graphs are never re-costed); a fresh candidate is costed
//! incrementally from its parent via [`CostModel::delta_runtime_ms`].

use std::collections::HashMap;

use crate::cost::CostModel;
use crate::graph::{canonical_hash, Graph};
use crate::xfer::{apply_rule, RuleSet};

/// Cross-depth memo of every graph the search has costed, keyed by
/// [`canonical_hash`] — the ruler/equality-saturation dedup idiom: two
/// substitution sequences reaching the same graph share one table slot.
#[derive(Debug, Clone, Default)]
pub struct TranspositionTable {
    map: HashMap<u64, f64>,
    /// Candidates served from the table instead of being re-costed, plus
    /// (in dedup mode) candidates dropped as already explored.
    pub hits: usize,
}

impl TranspositionTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.map.contains_key(&hash)
    }

    pub fn get(&self, hash: u64) -> Option<f64> {
        self.map.get(&hash).copied()
    }

    /// Record a costed graph; returns `true` when the hash was fresh.
    /// A duplicate never clobbers the stored cost: the first (canonical-
    /// order) derivation's value is the one memo hits must keep returning.
    pub fn insert(&mut self, hash: u64, ms: f64) -> bool {
        match self.map.entry(hash) {
            std::collections::hash_map::Entry::Occupied(_) => false,
            std::collections::hash_map::Entry::Vacant(slot) => {
                slot.insert(ms);
                true
            }
        }
    }
}

/// One graph alive at the current search depth, with its tracked runtime.
#[derive(Debug, Clone)]
pub struct FrontierEntry {
    pub ms: f64,
    pub graph: Graph,
}

/// One expanded candidate, emitted in canonical enumeration order.
#[derive(Debug)]
pub struct Candidate {
    pub rule_name: &'static str,
    pub hash: u64,
    pub ms: f64,
    /// Present iff `ms` beat the expansion's keep threshold (everything
    /// else is recorded in the table but its graph is dropped worker-side).
    pub graph: Option<Graph>,
    /// The runtime came from the transposition table, not a fresh costing.
    pub memo_hit: bool,
}

struct PairOut {
    cands: Vec<Candidate>,
    /// Candidates skipped worker-side as already in the table (dedup mode).
    skipped: usize,
}

/// The beam/frontier state shared by the search baselines.
#[derive(Debug)]
pub struct Frontier {
    pub entries: Vec<FrontierEntry>,
    pub table: TranspositionTable,
}

impl Frontier {
    /// Seed the frontier (and the table) with the initial graph.
    pub fn new(graph: Graph, ms: f64) -> Self {
        let mut table = TranspositionTable::new();
        table.insert(canonical_hash(&graph), ms);
        Self { entries: vec![FrontierEntry { ms, graph }], table }
    }

    /// Expand every (entry, rule, location) site once and return the
    /// candidates in canonical order. Graphs are retained only for
    /// candidates costing below `keep_below` (and, when
    /// `best_only_per_pair` is set, only the cheapest kept candidate of
    /// each (entry, rule) pair — what greedy selection needs). With
    /// `drop_seen`, candidates whose hash is already in the table are
    /// dropped entirely (TASO's explored-set dedup); otherwise the table
    /// serves purely as a cost memo.
    ///
    /// The table itself is NOT updated here — callers fold the returned
    /// candidates in with [`TranspositionTable::insert`] so that in-depth
    /// duplicates resolve in canonical order. Worker-side skips are added
    /// to `table.hits`.
    pub fn expand(
        &mut self,
        rules: &RuleSet,
        cost: &CostModel,
        keep_below: f64,
        drop_seen: bool,
        best_only_per_pair: bool,
        threads: usize,
    ) -> Vec<Candidate> {
        let entries = &self.entries;
        let table = &self.table;
        let n_pairs = entries.len() * rules.len();
        // Measurement noise draws per costing call: sharding would make
        // draws depend on worker assignment, so noisy models always expand
        // sequentially (the same downgrade `search::resolve_threads`
        // applies — enforced here too so direct `Frontier` users keep the
        // bit-identical contract).
        let threads = if cost.noise_std > 0.0 {
            1
        } else {
            effective_threads(threads, n_pairs)
        };

        // One const set per parent graph: identical for all of a parent's
        // candidates, so don't recompute it per (rule, location) site.
        let parent_consts: Vec<Vec<bool>> =
            entries.iter().map(|e| cost.const_set(&e.graph)).collect();
        let parent_consts = &parent_consts;

        let expand_pair = |entry_idx: usize, rule_idx: usize, cm: &CostModel| -> PairOut {
            let parent = &entries[entry_idx];
            let rule = rules.rules[rule_idx].as_ref();
            let mut cands: Vec<Candidate> = Vec::new();
            let mut skipped = 0usize;
            let mut best_kept: Option<usize> = None;
            for loc in rule.find(&parent.graph) {
                let mut candidate = parent.graph.clone();
                let report = match apply_rule(&mut candidate, rule, &loc) {
                    Ok(r) => r,
                    Err(_) => continue,
                };
                let hash = canonical_hash(&candidate);
                if drop_seen && table.contains(hash) {
                    skipped += 1;
                    continue;
                }
                let (ms, memo_hit) = match table.get(hash) {
                    Some(ms) => (ms, true),
                    None => (
                        cm.delta_runtime_ms_with(
                            &parent.graph,
                            &parent_consts[entry_idx],
                            parent.ms,
                            &candidate,
                            &report,
                        ),
                        false,
                    ),
                };
                let keep = ms < keep_below;
                if keep {
                    let better = match best_kept {
                        Some(b) => ms < cands[b].ms,
                        None => true,
                    };
                    if better {
                        best_kept = Some(cands.len());
                    }
                }
                cands.push(Candidate {
                    rule_name: rule.name(),
                    hash,
                    ms,
                    graph: keep.then_some(candidate),
                    memo_hit,
                });
            }
            if best_only_per_pair {
                for (i, c) in cands.iter_mut().enumerate() {
                    if Some(i) != best_kept {
                        c.graph = None;
                    }
                }
            }
            PairOut { cands, skipped }
        };

        // Pairs in canonical order: frontier entries major, rules minor.
        let n_rules = rules.len();
        let pair_of = move |i: usize| (i / n_rules, i % n_rules);

        let mut outs: Vec<Option<PairOut>> = (0..n_pairs).map(|_| None).collect();
        if threads <= 1 {
            for (i, slot) in outs.iter_mut().enumerate() {
                let (e, r) = pair_of(i);
                *slot = Some(expand_pair(e, r, cost));
            }
        } else {
            // Workers take pairs round-robin (cheap load balancing); the
            // merge below restores canonical order regardless. Each worker
            // shares the parent's frozen memo snapshot and keeps only its
            // fresh entries in a private overlay — no per-depth copy of the
            // whole cache. (Noisy models never reach here, so the
            // snapshot's noise-free default is exact.)
            let snap = cost.snapshot();
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for w in 0..threads {
                    let expand_pair = &expand_pair;
                    let cm = CostModel::from_snapshot(&snap);
                    handles.push(scope.spawn(move || {
                        let mut mine: Vec<(usize, PairOut)> = Vec::new();
                        let mut i = w;
                        while i < n_pairs {
                            let (e, r) = pair_of(i);
                            mine.push((i, expand_pair(e, r, &cm)));
                            i += threads;
                        }
                        (mine, cm)
                    }));
                }
                for h in handles {
                    let (mine, cm) = h.join().expect("search worker panicked");
                    // Fold the worker's freshly computed op costs back so
                    // the next depth's clones start warm.
                    cost.absorb_cache(&cm);
                    for (i, out) in mine {
                        outs[i] = Some(out);
                    }
                }
            });
        }

        let mut cands = Vec::new();
        for out in outs.into_iter().flatten() {
            self.table.hits += out.skipped;
            cands.extend(out.cands);
        }
        cands
    }
}

/// Resolve a requested thread count: 0 means "all available cores",
/// bounded by the number of work items.
pub(crate) fn effective_threads(requested: usize, work_items: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 { hw } else { requested };
    t.min(work_items).max(1)
}
