//! Shared frontier machinery behind both deterministic search baselines.
//!
//! [`Frontier`] owns the graphs alive at the current search depth plus the
//! cross-depth [`TranspositionTable`]; [`Frontier::expand`] is the one
//! candidate-generation path both `greedy_optimise` and `taso_optimise`
//! call.
//!
//! # Location-level sharding
//!
//! Expansion fans individual **(frontier entry, rule, match location)**
//! sites out across scoped worker threads — the same worker-owns-its-model
//! pattern as `env::EnvPool`: the `RuleSet` is `Sync` and is shared by
//! reference, while each worker owns a [`CostModel`] built from the
//! parent's shared read-only memo snapshot plus a small private overlay
//! (interior mutability makes the cost model deliberately `!Sync`). Because
//! the work unit is one location rather than one `(entry, rule)` pair, a
//! single match-heavy rule (`fuse_add_ln` on a transformer has one site per
//! residual block) no longer serialises a depth behind one worker.
//!
//! Per-site sharding needs the match locations *before* the fan-out, but
//! running `Rule::find` once to count and again to apply would double the
//! matching work. Instead every [`FrontierEntry`] carries its own
//! [`MatchCache`] (the incremental per-rule match lists the environment
//! core introduced): the root entry runs one full find, and each surviving
//! candidate's lists are derived from its parent's by patching only the
//! rules that can intersect the rewrite's `DirtyRegion`
//! ([`Frontier::entry_from_candidate`]). `Rule::find` therefore runs
//! exactly once per (entry, invalidated rule) — never per work item, and
//! never twice for the same lists.
//!
//! # Determinism
//!
//! Workers take sites round-robin but results are merged back in canonical
//! (frontier entry, rule, location) enumeration order, and all
//! transposition-table updates happen on the caller's thread during that
//! merge. The candidate stream is therefore *bit-identical* for every
//! thread count, which the search property tests pin down. Measurement
//! noise no longer forces a sequential downgrade: the noise model is a
//! stateless per-kernel field (see `cost`), so noisy expansions parallelise
//! exactly like clean ones.
//!
//! # Costing
//!
//! A candidate already in the table (or in the table's read-only *base*
//! layer seeded from a persistent [`SearchCache`]) reuses the memoised
//! runtime; a fresh candidate is costed incrementally from its parent via
//! [`CostModel::delta_runtime_ms`].
//!
//! [`MatchCache`]: crate::env::MatchCache
//! [`SearchCache`]: crate::search::SearchCache

use std::collections::HashMap;
use std::sync::Arc;

use crate::cost::CostModel;
use crate::env::MatchCache;
use crate::graph::{canonical_hash, Graph};
use crate::xfer::{apply_rule, ApplyReport, RuleSet};

/// Cross-depth memo of every graph the search has costed, keyed by
/// [`canonical_hash`] — the ruler/equality-saturation dedup idiom: two
/// substitution sequences reaching the same graph share one table slot.
///
/// The table has two layers. The **local** map holds graphs costed by *this
/// run*; it doubles as TASO's explored-set, so [`TranspositionTable::insert`]
/// and [`TranspositionTable::contains`] see only it. The optional **base**
/// layer is a frozen map inherited from a persistent
/// [`SearchCache`](crate::search::SearchCache): [`TranspositionTable::get`]
/// falls through to it, so costs memoised by earlier runs with the same
/// config fingerprint are reused without ever polluting this run's
/// explored-set semantics (a graph another run explored is still a fresh
/// candidate here). Base-served costs carry the *first derivation's* f64
/// value, which can differ from a fresh derivation's in the last ulps —
/// the same summation-order caveat in-run memoisation already has against
/// the `_reference` oracles; exact near-ties may therefore resolve
/// differently warm vs `--fresh-cache`, while repeated identical searches
/// stay bit-identical through the result memo.
#[derive(Debug, Clone, Default)]
pub struct TranspositionTable {
    map: HashMap<u64, f64>,
    /// Read-only cost entries inherited across runs (empty when the search
    /// runs without a persistent cache).
    base: Arc<HashMap<u64, f64>>,
    /// Candidates served from the table instead of being re-costed, plus
    /// (in dedup mode) candidates dropped as already explored.
    pub hits: usize,
}

impl TranspositionTable {
    /// An empty table with no inherited base layer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install the read-only cost layer inherited from a persistent cache.
    pub fn set_base(&mut self, base: Arc<HashMap<u64, f64>>) {
        self.base = base;
    }

    /// Number of graphs costed by *this run* (the base layer is excluded).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when this run has not costed any graph yet.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Entries inherited from the persistent cache (not explored this run).
    pub fn base_len(&self) -> usize {
        self.base.len()
    }

    /// Was `hash` explored by *this run*? (Explored-set semantics: the
    /// inherited base layer deliberately does not count — a graph another
    /// run explored is still a fresh candidate for this one.)
    pub fn contains(&self, hash: u64) -> bool {
        self.map.contains_key(&hash)
    }

    /// Memoised runtime for `hash`, if any: this run's entry first (the
    /// in-run first derivation stays canonical), then the inherited base.
    pub fn get(&self, hash: u64) -> Option<f64> {
        self.map
            .get(&hash)
            .or_else(|| self.base.get(&hash))
            .copied()
    }

    /// Record a costed graph; returns `true` when the hash was fresh.
    /// A duplicate never clobbers the stored cost: the first (canonical-
    /// order) derivation's value is the one memo hits must keep returning.
    pub fn insert(&mut self, hash: u64, ms: f64) -> bool {
        match self.map.entry(hash) {
            std::collections::hash_map::Entry::Occupied(_) => false,
            std::collections::hash_map::Entry::Vacant(slot) => {
                slot.insert(ms);
                true
            }
        }
    }

    /// This run's fresh entries (hash, runtime) — what a persistent cache
    /// absorbs back after the search ends.
    pub fn local_entries(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        self.map.iter().map(|(&k, &v)| (k, v))
    }
}

/// One graph alive at the current search depth, with its tracked runtime
/// and its incrementally-maintained per-rule match lists.
#[derive(Debug, Clone)]
pub struct FrontierEntry {
    /// Tracked runtime of `graph` (memo/delta value; the full recompute is
    /// re-run once at search end).
    pub ms: f64,
    /// The graph itself.
    pub graph: Graph,
    /// Per-rule match lists for `graph`. Maintained incrementally from the
    /// parent entry's lists (see the module docs), and always equal to a
    /// from-scratch `Rule::find` pass — the invariant
    /// `tests/env_incremental.rs` pins for the environment's cache.
    pub matches: MatchCache,
}

/// One expanded candidate, emitted in canonical enumeration order.
#[derive(Debug)]
pub struct Candidate {
    /// Name of the rule that produced this candidate.
    pub rule_name: &'static str,
    /// Index of the frontier entry this candidate was expanded from.
    pub entry_idx: usize,
    /// Canonical hash of the candidate graph.
    pub hash: u64,
    /// Tracked runtime (memoised or incrementally costed).
    pub ms: f64,
    /// Present iff `ms` beat the expansion's keep threshold (everything
    /// else is recorded in the table but its graph is dropped worker-side).
    pub graph: Option<Graph>,
    /// The application's live-set diff; present iff `graph` is (survivor
    /// entries need it to patch their match lists).
    pub report: Option<ApplyReport>,
    /// The runtime came from the transposition table (either layer), not a
    /// fresh costing.
    pub memo_hit: bool,
}

struct ItemOut {
    /// The expanded candidate; `None` when the application failed or the
    /// site was dropped as already explored.
    cand: Option<Candidate>,
    /// The site was skipped worker-side as already in the table (dedup
    /// mode).
    seen: bool,
}

/// The beam/frontier state shared by the search baselines.
#[derive(Debug)]
pub struct Frontier {
    /// Graphs alive at the current depth.
    pub entries: Vec<FrontierEntry>,
    /// Cross-depth cost memo + explored-set.
    pub table: TranspositionTable,
}

impl Frontier {
    /// Seed the frontier (and the table) with the initial graph. Runs the
    /// one full `Rule::find` pass of the whole search; every later match
    /// list derives incrementally from this one.
    pub fn new(graph: Graph, ms: f64, rules: &RuleSet) -> Self {
        let mut table = TranspositionTable::new();
        table.insert(canonical_hash(&graph), ms);
        let matches = MatchCache::full(rules, &graph);
        Self { entries: vec![FrontierEntry { ms, graph, matches }], table }
    }

    /// Build the next-depth entry for a kept candidate: clone the parent's
    /// match lists and re-find only the rules whose patterns can intersect
    /// the rewrite's dirty region. `Rule::find` is never run for the
    /// untouched rules — their lists are provably byte-identical.
    pub fn entry_from_candidate(&self, rules: &RuleSet, c: Candidate) -> FrontierEntry {
        let parent = &self.entries[c.entry_idx];
        let graph = c.graph.expect("only kept candidates become frontier entries");
        let report = c.report.expect("kept candidates carry their apply report");
        let dirty = report.dirty_region(&parent.graph, &graph);
        let mut matches = parent.matches.clone();
        matches.refresh(rules, &graph, &dirty);
        FrontierEntry { ms: c.ms, graph, matches }
    }

    /// Expand every (entry, rule, location) site once and return the
    /// candidates in canonical order. Graphs (and their apply reports) are
    /// retained only for candidates costing below `keep_below`; with
    /// `best_only`, each worker stripe additionally keeps the graph of only
    /// its earliest-minimal kept candidate (greedy consumes one global
    /// argmin, so retaining more is pure memory — and the global
    /// earliest-min is always some stripe's earliest-min, so selection is
    /// unchanged). With `drop_seen`, sites whose result hash is already in
    /// this run's table are dropped entirely (TASO's explored-set dedup);
    /// otherwise the table serves purely as a cost memo.
    ///
    /// The table itself is NOT updated here — callers fold the returned
    /// candidates in with [`TranspositionTable::insert`] so that in-depth
    /// duplicates resolve in canonical order. Worker-side skips are added
    /// to `table.hits`.
    pub fn expand(
        &mut self,
        rules: &RuleSet,
        cost: &CostModel,
        keep_below: f64,
        drop_seen: bool,
        best_only: bool,
        threads: usize,
    ) -> Vec<Candidate> {
        let entries = &self.entries;
        let table = &self.table;

        // Work items at (entry, rule, location) granularity, flattened in
        // canonical enumeration order. The index into this vec IS the merge
        // order, so thread assignment cannot reorder the candidate stream.
        let mut items: Vec<(u32, u32, u32)> = Vec::new();
        for (e, entry) in entries.iter().enumerate() {
            for (r, list) in entry.matches.lists().iter().enumerate() {
                for l in 0..list.len() {
                    items.push((e as u32, r as u32, l as u32));
                }
            }
        }
        let n_items = items.len();
        let threads = effective_threads(threads, n_items);
        let items = &items;

        // One const set per parent graph: identical for all of a parent's
        // candidates, so don't recompute it per site.
        let parent_consts: Vec<Vec<bool>> =
            entries.iter().map(|e| cost.const_set(&e.graph)).collect();
        let parent_consts = &parent_consts;

        let expand_item = |i: usize, cm: &CostModel| -> ItemOut {
            let (e, r, l) = items[i];
            let (e, r, l) = (e as usize, r as usize, l as usize);
            let parent = &entries[e];
            let rule = rules.rules[r].as_ref();
            let loc = &parent.matches.lists()[r][l];
            let mut candidate = parent.graph.clone();
            let report = match apply_rule(&mut candidate, rule, loc) {
                Ok(rep) => rep,
                Err(_) => return ItemOut { cand: None, seen: false },
            };
            let hash = canonical_hash(&candidate);
            if drop_seen && table.contains(hash) {
                return ItemOut { cand: None, seen: true };
            }
            let (ms, memo_hit) = match table.get(hash) {
                Some(ms) => (ms, true),
                None => (
                    cm.delta_runtime_ms_with(
                        &parent.graph,
                        &parent_consts[e],
                        parent.ms,
                        &candidate,
                        &report,
                    ),
                    false,
                ),
            };
            let keep = ms < keep_below;
            let (graph, report) = if keep {
                (Some(candidate), Some(report))
            } else {
                (None, None)
            };
            ItemOut {
                cand: Some(Candidate {
                    rule_name: rule.name(),
                    entry_idx: e,
                    hash,
                    ms,
                    graph,
                    report,
                    memo_hit,
                }),
                seen: false,
            }
        };

        // One round-robin stripe of the work items. With `best_only`, the
        // stripe nulls the graph/report of every kept candidate except its
        // earliest-minimal one (strict `<`, ascending site order) as it
        // goes, so peak memory stays at one retained graph per stripe.
        let run_stripe = |w: usize, stride: usize, cm: &CostModel| -> Vec<(usize, ItemOut)> {
            let mut mine: Vec<(usize, ItemOut)> = Vec::new();
            let mut best_kept: Option<(usize, f64)> = None; // (index into mine, ms)
            let mut i = w;
            while i < n_items {
                let out = expand_item(i, cm);
                mine.push((i, out));
                if best_only {
                    let last = mine.len() - 1;
                    let kept_ms = mine[last]
                        .1
                        .cand
                        .as_ref()
                        .and_then(|c| c.graph.as_ref().map(|_| c.ms));
                    if let Some(ms) = kept_ms {
                        match best_kept {
                            Some((prev, best_ms)) if ms < best_ms => {
                                let c = mine[prev].1.cand.as_mut().expect("kept candidate");
                                c.graph = None;
                                c.report = None;
                                best_kept = Some((last, ms));
                            }
                            Some(_) => {
                                let c = mine[last].1.cand.as_mut().expect("kept candidate");
                                c.graph = None;
                                c.report = None;
                            }
                            None => best_kept = Some((last, ms)),
                        }
                    }
                }
                i += stride;
            }
            mine
        };

        let mut outs: Vec<Option<ItemOut>> = (0..n_items).map(|_| None).collect();
        if threads <= 1 {
            for (i, out) in run_stripe(0, 1, cost) {
                outs[i] = Some(out);
            }
        } else {
            // Workers take sites round-robin (cheap load balancing); the
            // merge below restores canonical order regardless. Each worker
            // shares the parent's frozen memo snapshot and keeps only its
            // fresh entries in a private overlay — no per-depth copy of the
            // whole cache. Workers inherit the parent's noise field, so
            // noisy costs are bit-identical to the sequential path.
            let snap = cost.snapshot();
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for w in 0..threads {
                    let run_stripe = &run_stripe;
                    let cm = CostModel::from_snapshot(&snap).with_noise_of(cost);
                    handles.push(scope.spawn(move || {
                        let mine = run_stripe(w, threads, &cm);
                        (mine, cm)
                    }));
                }
                for h in handles {
                    let (mine, cm) = h.join().expect("search worker panicked");
                    // Fold the worker's freshly computed op costs back so
                    // the next depth's clones start warm.
                    cost.absorb_cache(&cm);
                    for (i, out) in mine {
                        outs[i] = Some(out);
                    }
                }
            });
        }

        let mut cands = Vec::new();
        for out in outs.into_iter().flatten() {
            self.table.hits += out.seen as usize;
            cands.extend(out.cand);
        }
        cands
    }
}

/// Resolve a requested thread count: 0 means "all available cores",
/// bounded by the number of work items.
pub(crate) fn effective_threads(requested: usize, work_items: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 { hw } else { requested };
    t.min(work_items).max(1)
}
