//! `rlflow` — command-line interface to the RLFlow system.
//!
//! ```text
//! rlflow zoo                               list the evaluation graphs
//! rlflow optimize --graph bert --method taso|greedy [--threads N] [--rules rules.json] [--export out.json]
//! rlflow train --graph bert [--backend host|pjrt|auto] [--envs B] [--config cfg.json] [-s key=value ...]
//! rlflow eval --load dir [--graph bert] [--backend host|pjrt|auto]
//! rlflow experiment <table1|table2|table3|fig5..fig10|all> [--runs N] [--rules rules.json]
//! rlflow synth --out rules.json [--alphabet groups] [--ops N] [--inputs N] [--seed S] [--tier T]
//! rlflow generate-rules [--verify]
//! rlflow serve --addr 127.0.0.1:7777 [--cache-dir DIR] [--workers N] [--queue N] [--timeout-ms T]
//! rlflow request [--addr A] --graph bert [--method taso|greedy] | --stats | --ping | --shutdown
//! ```
//!
//! Config resolution: defaults -> `--config file.json` -> `-s key=value`.
//! `--backend host` runs the whole train/dream/eval loop on the pure-Rust
//! [`rlflow::runtime::HostBackend`] — no artifacts, no `xla_extension`.

use rlflow::config::RunConfig;
use rlflow::coordinator::{Checkpoint, CheckpointCfg, Pipeline};
use rlflow::cost::CostModel;
use rlflow::experiments::{self, ExperimentCtx};
use rlflow::runtime::{backend_by_name, Backend, ParamStore};
use rlflow::search::{
    greedy_optimise_cached, memo, taso_optimise_cached, SearchCache, TasoConfig,
};
use rlflow::xfer::library::standard_library;
use rlflow::xfer::Rule;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
    overrides: Vec<String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut overrides = Vec::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(arg) = it.next() {
        if arg == "-s" || arg == "--set" {
            if let Some(v) = it.next() {
                overrides.push(v);
            }
        } else if let Some(name) = arg.strip_prefix("--") {
            let value = if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), value);
        } else {
            positional.push(arg);
        }
    }
    Args { positional, flags, overrides }
}

fn build_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = if args.flags.get("smoke").map(|v| v == "true").unwrap_or(false) {
        RunConfig::smoke()
    } else {
        RunConfig::default()
    };
    if let Some(path) = args.flags.get("config") {
        let text = std::fs::read_to_string(path)?;
        cfg.apply_json(&rlflow::util::json::parse(&text)?)?;
    }
    if let Some(g) = args.flags.get("graph") {
        cfg.graph = g.clone();
    }
    // `--envs B`: width of the batched EnvPool used by rollout collection
    // and evaluation (equivalent to `-s envs=B`).
    if let Some(e) = args.flags.get("envs") {
        cfg.envs = e
            .parse()
            .map_err(|err| anyhow::anyhow!("bad --envs '{e}': {err}"))?;
    }
    // `--backend host|pjrt|auto` (equivalent to `-s backend=...`).
    if let Some(b) = args.flags.get("backend") {
        cfg.backend = b.clone();
    }
    for o in &args.overrides {
        cfg.apply_override(o)?;
    }
    Ok(cfg)
}

fn main() -> anyhow::Result<()> {
    let args = parse_args();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "zoo" => cmd_zoo(),
        "optimize" => cmd_optimize(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "experiment" => cmd_experiment(&args),
        "synth" => cmd_synth(&args),
        "generate-rules" => cmd_generate_rules(&args),
        "serve" => cmd_serve(&args),
        "request" => cmd_request(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
rlflow — neural-network subgraph transformation with world models

USAGE:
  rlflow zoo
  rlflow optimize --graph <name> --method <greedy|taso> [--threads N] [--repeat N] [--fresh-cache] [--rules rules.json] [--export out.json]
  rlflow train [--graph <name>] [--backend host|pjrt|auto] [--envs B] [--config cfg.json] [--smoke] [--save dir] [-s key=value]...
  rlflow train --async [--replay trace.txt] [--trace out.txt] [... train flags]
  rlflow train [--async] --checkpoint-every N [--checkpoint-dir D] | --resume D [... train flags]
  rlflow eval --load <dir> [--graph <name>] [--backend host|pjrt|auto] [--envs B] [-s key=value]...
  rlflow experiment <table1|table2|table3|fig5|...|fig10|all> [--runs N] [--backend B] [--envs B] [--smoke] [--out dir] [--fresh-cache] [--rules rules.json]
  rlflow synth --out <rules.json> [--alphabet <groups|all>] [--inputs N] [--ops N] [--seed S] [--tier <always-safe|shape-preserving|all>] [--max-rules N]
  rlflow generate-rules [--verify] [--inputs N] [--ops N]
  rlflow serve [--addr 127.0.0.1:7777] [--cache-dir DIR] [--workers N] [--queue N] [--timeout-ms T] [--threads N] [--snapshot-every N]
  rlflow request [--addr A] (--graph <name> | --import model.json) [--method greedy|taso] [--timeout-ms T] [--retries N] [--retry-budget-ms T] [--export out.json]
  rlflow request [--addr A] --stats | --ping | --shutdown

RULE SYNTHESIS:
  `rlflow synth` enumerates small graphs over the requested op alphabet
  (groups: ewise, act, shape, matmul, scale, fused — comma-separated, or
  `all`), verifies substitution candidates with the reference interpreter,
  tiers them (always-safe ⊂ shape-preserving ⊂ all) and writes a ruleset
  file. `--rules rules.json` on optimize/experiment appends the
  synthesised rules to the handwritten library for search (the combined
  vocabulary gets its own search-cache fingerprint).

CACHING:
  optimize/experiment hold a persistent search cache: repeated identical
  searches (same graph, same config) are pure lookups, and the
  transposition table persists across searches sharing a config.
  --fresh-cache starts from an empty cache instead; hit/miss/evict stats
  are printed after each command.

SERVING:
  `rlflow serve` runs a long-lived optimisation daemon on a newline-
  delimited JSON protocol: request = graph + search config, response =
  optimised graph + cost log + cache provenance (fresh|cache|coalesced).
  With --cache-dir the search cache persists on disk (append-only log +
  compacted snapshots) and warm restarts answer previously served
  requests bit-identically. Concurrent identical requests coalesce into
  one search; a full queue sheds load with a typed `overloaded` error.
  `rlflow request` is the matching client (--stats/--ping/--shutdown for
  control; shutdown drains in-flight work, snapshots and exits).

ASYNC TRAINING:
  `rlflow train --async` runs the pipelined actor/learner trainer: env
  shards stream trajectories through a bounded staging buffer while the
  learner stages (GNN-AE, encoder, world model, dream-PPO, eval) train
  on the previous round. Every cross-stage handoff is recorded to a
  schedule trace (`--trace out.txt`, or `dir/trace.txt` with --save);
  `--replay trace.txt` re-executes that exact schedule — same seeds +
  same trace => bit-identical final params. Knobs: -s async_rounds=N,
  -s async_stage_threads=N, -s async_staging_cap=N (thread counts never
  change results, only timing).

CRASH SAFETY:
  `rlflow train --checkpoint-every N` writes an atomic, checksummed
  checkpoint (params + optimiser moments + every RNG stream + replay
  pools + eval history) into --checkpoint-dir after every N rounds;
  `--resume DIR` loads the newest valid checkpoint and continues.
  Interrupting at any round boundary and resuming is bit-identical to
  the uninterrupted run, for both the synchronous round engine and
  --async (any stage-thread count). Without --async, checkpointing runs
  the same round engine as --async with a canonical schedule.
  `rlflow request --retries N` retries transient failures (`overloaded`,
  `timeout`, connection refused/dropped) with seeded-jitter exponential
  backoff capped by --retry-budget-ms; `bad_request` is never retried.

BACKENDS:
  host   pure-Rust model execution — the full collect/WM/dream/PPO/eval
         loop runs offline with no artifacts and no xla_extension
  pjrt   AOT-compiled XLA artifacts (requires `make artifacts` + a linked
         xla_extension)
  auto   pjrt when artifacts/manifest.json exists, host otherwise (default)
";

fn cmd_zoo() -> anyhow::Result<()> {
    let rules = standard_library();
    let cost = CostModel::new(rlflow::cost::DeviceProfile::rtx2070());
    println!(
        "{:<15} {:>6} {:>8} {:>12} {:>14}",
        "Graph", "Ops", "Nodes", "Runtime(ms)", "Substitutions"
    );
    for (info, g) in rlflow::zoo::all() {
        println!(
            "{:<15} {:>6} {:>8} {:>12.3} {:>14}",
            info.name,
            g.n_ops(),
            g.n_live(),
            cost.graph_runtime_ms(&g),
            rules.count_matches(&g)
        );
    }
    Ok(())
}

/// Select the search cache a command runs against: the process-global one
/// (persists across every search this process performs) unless
/// `--fresh-cache` asked for an empty private cache.
fn search_cache(args: &Args) -> std::sync::Arc<SearchCache> {
    if args.flags.get("fresh-cache").map(|v| v == "true").unwrap_or(false) {
        std::sync::Arc::new(SearchCache::new())
    } else {
        memo::global()
    }
}

fn cmd_optimize(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    let graph = rlflow::zoo::by_name(&cfg.graph)?;
    // `--rules path`: extend the handwritten library with a synthesised
    // ruleset file (from `rlflow synth`) for this search.
    let rules_path = args.flags.get("rules").map(String::as_str);
    let rules = rlflow::xfer::synth::library_with_rules(rules_path)?;
    // Honours `-s cost_noise=...` (the noise config is part of the search
    // cache fingerprint, so noisy and clean runs never alias).
    let cost = cfg.cost_model();
    let method = args.flags.get("method").map(String::as_str).unwrap_or("taso");
    // `--threads N` pins the search worker count (0/default = all cores);
    // results are bit-identical for every value.
    let threads: usize = match args.flags.get("threads") {
        Some(t) => t
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --threads '{t}': {e}"))?,
        None => 0,
    };
    // `--repeat N` re-runs the search N times — with the persistent cache
    // every repeat after the first is a pure lookup (demo/benchmark knob).
    let repeat: usize = args
        .flags
        .get("repeat")
        .map(|r| r.parse())
        .transpose()
        .map_err(|e| anyhow::anyhow!("bad --repeat: {e}"))?
        .unwrap_or(1)
        .max(1);
    let cache = search_cache(args);
    let mut result = None;
    for _ in 0..repeat {
        result = Some(match method {
            "greedy" => greedy_optimise_cached(&graph, &rules, &cost, 100, threads, &cache),
            "taso" => taso_optimise_cached(
                &graph,
                &rules,
                &cost,
                &TasoConfig { threads, ..Default::default() },
                &cache,
            ),
            m => anyhow::bail!("unknown method '{m}' (greedy|taso; for RL use `rlflow train`)"),
        });
    }
    let (optimised, log) = result.expect("repeat >= 1 always runs the search");
    println!(
        "{}: {:.3} ms -> {:.3} ms ({:.1}% better) in {:.2}s, {} graphs explored ({} threads, {} memo hits{})",
        cfg.graph,
        log.initial_ms,
        log.final_ms,
        log.improvement_pct(),
        log.elapsed_s,
        log.graphs_explored,
        log.threads,
        log.memo_hits,
        if log.from_cache { ", cached result" } else { "" }
    );
    println!("search cache: {}", cache.stats());
    for (rule, ms) in &log.steps {
        println!("  applied {:<22} -> {:.3} ms", rule, ms);
    }
    if let Some(path) = args.flags.get("export") {
        rlflow::graph::onnx::save(&optimised, &cfg.graph, path)?;
        println!("exported optimised graph to {path}");
    }
    Ok(())
}

/// Parse `--checkpoint-every`/`--checkpoint-dir`/`--resume` onto the
/// config, and load the checkpoint a `--resume DIR` run continues from
/// (`--resume` also points the checkpoint directory at DIR).
fn checkpoint_setup(
    args: &Args,
    cfg: &mut RunConfig,
) -> anyhow::Result<(Option<CheckpointCfg>, Option<Checkpoint>)> {
    cfg.checkpoint_every = usize_flag(args, "checkpoint-every", cfg.checkpoint_every)?;
    if let Some(d) = args.flags.get("checkpoint-dir") {
        cfg.checkpoint_dir = d.clone();
    }
    let resume = match args.flags.get("resume") {
        Some(dir) => {
            cfg.checkpoint_dir = dir.clone();
            let cp = Checkpoint::load_latest(std::path::Path::new(dir))?.ok_or_else(|| {
                anyhow::anyhow!("--resume {dir}: no usable checkpoint found there")
            })?;
            println!("resuming from {dir}/ at round {}", cp.next_round);
            Some(cp)
        }
        None => None,
    };
    let ckpt = (cfg.checkpoint_every > 0).then(|| CheckpointCfg {
        dir: std::path::PathBuf::from(&cfg.checkpoint_dir),
        every: cfg.checkpoint_every,
    });
    Ok((ckpt, resume))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = build_config(args)?;
    // `--async` (equivalent to `-s async=true`): the pipelined
    // actor/learner path with its schedule-trace determinism contract.
    if args.flags.get("async").map(|v| v == "true").unwrap_or(false) {
        cfg.train_async = true;
    }
    let (ckpt, resume) = checkpoint_setup(args, &mut cfg)?;
    if cfg.train_async {
        return cmd_train_async(args, &cfg, ckpt, resume);
    }
    if ckpt.is_some() || resume.is_some() {
        // Checkpointing requires the round engine (the single-pass
        // model-based pipeline has no round boundaries to snapshot at).
        return cmd_train_rounds(args, &cfg, ckpt, resume);
    }
    let backend = backend_by_name(&cfg.backend)?;
    let pipe = Pipeline::new(backend.as_ref())?;
    let graph = rlflow::zoo::by_name(&cfg.graph)?;
    println!(
        "training model-based agent on {} (seed {}, backend {})",
        cfg.graph,
        cfg.seed,
        backend.name()
    );
    let agent = experiments::train_model_based(&pipe, &cfg, &graph, cfg.seed)?;
    for (stage, secs) in &agent.stage_seconds {
        println!("  {:<12} {:.1}s", stage, secs);
    }
    let (scores, _, mean_step) =
        experiments::eval_agent(&pipe, &cfg, &agent, &graph, cfg.eval_episodes, cfg.seed)?;
    let (m, s) = rlflow::util::stats::mean_std(&scores);
    println!(
        "eval: {:.2}% ± {:.2} improvement over {} runs ({:.1} ms/step)",
        m,
        s,
        scores.len(),
        mean_step * 1e3
    );

    if let Some(dir) = args.flags.get("save") {
        std::fs::create_dir_all(dir)?;
        agent.gnn.save(format!("{dir}/gnn.rlw"))?;
        agent.wm.save(format!("{dir}/wm.rlw"))?;
        agent.ctrl.save(format!("{dir}/ctrl.rlw"))?;
        println!("saved parameters to {dir}/");
    }
    Ok(())
}

/// `rlflow train --async`: the pipelined actor/learner trainer. Records
/// a schedule trace of every cross-stage handoff; `--replay trace.txt`
/// re-executes a recorded schedule instead (same seeds + same trace =>
/// bit-identical final params — diffable with `cmp` on the saved .rlw
/// files). `--checkpoint-every`/`--resume` add crash safety.
fn cmd_train_async(
    args: &Args,
    cfg: &RunConfig,
    ckpt: Option<CheckpointCfg>,
    resume: Option<Checkpoint>,
) -> anyhow::Result<()> {
    use rlflow::coordinator::{replay_trace, train_async_ckpt, AsyncTrainCfg, ScheduleTrace};
    let acfg = AsyncTrainCfg::from_run(cfg);
    let graph = rlflow::zoo::by_name(&cfg.graph)?;
    // Each stage thread builds its own backend instance via the factory
    // (backends hold single-threaded interior state).
    let backend_name = cfg.backend.clone();
    let factory = move || backend_by_name(&backend_name);

    let out = if let Some(path) = args.flags.get("replay") {
        anyhow::ensure!(resume.is_none(), "--replay cannot be combined with --resume");
        anyhow::ensure!(ckpt.is_none(), "--replay cannot be combined with --checkpoint-every");
        let trace = ScheduleTrace::load(std::path::Path::new(path))?;
        println!(
            "replaying schedule {path} on {} (seed {}, {} rounds, {} envs)",
            cfg.graph, cfg.seed, trace.rounds, trace.envs
        );
        replay_trace(&factory, cfg, &acfg, &graph, &trace)?
    } else {
        println!(
            "training async pipeline on {} (seed {}, {} rounds, {} stage threads, staging cap {})",
            cfg.graph, cfg.seed, acfg.rounds, acfg.stage_threads, acfg.staging_cap
        );
        train_async_ckpt(&factory, cfg, &acfg, &graph, ckpt.as_ref(), resume)?
    };
    report_round_outcome(args, &out)
}

/// `rlflow train --checkpoint-every/--resume` without `--async`: the same
/// round engine as the async pipeline, executed sequentially under the
/// canonical schedule, with atomic checkpoints at round boundaries.
fn cmd_train_rounds(
    args: &Args,
    cfg: &RunConfig,
    ckpt: Option<CheckpointCfg>,
    resume: Option<Checkpoint>,
) -> anyhow::Result<()> {
    use rlflow::coordinator::{train_reference_ckpt, AsyncTrainCfg};
    let acfg = AsyncTrainCfg::from_run(cfg);
    let graph = rlflow::zoo::by_name(&cfg.graph)?;
    let backend_name = cfg.backend.clone();
    let factory = move || backend_by_name(&backend_name);
    println!(
        "training round engine on {} (seed {}, {} rounds, checkpoints in {})",
        cfg.graph, cfg.seed, acfg.rounds, cfg.checkpoint_dir
    );
    let out = train_reference_ckpt(&factory, cfg, &acfg, &graph, ckpt.as_ref(), resume)?;
    report_round_outcome(args, &out)
}

/// Print per-round eval summaries and honour `--trace`/`--save` for a
/// round-engine outcome (shared by `--async` and the checkpointing
/// synchronous path).
fn report_round_outcome(
    args: &Args,
    out: &rlflow::coordinator::AsyncOutcome,
) -> anyhow::Result<()> {
    for re in &out.evals {
        let scores: Vec<f64> = re.results.iter().map(|r| r.best_improvement_pct).collect();
        let (m, s) = rlflow::util::stats::mean_std(&scores);
        println!(
            "  round {:<2} eval: {:.2}% ± {:.2} improvement over {} runs",
            re.round,
            m,
            s,
            scores.len()
        );
    }
    println!(
        "schedule trace: {} handoffs over {} rounds x {} env shards",
        out.trace.events.len(),
        out.trace.rounds,
        out.trace.envs
    );

    if let Some(path) = args.flags.get("trace") {
        out.trace.save(std::path::Path::new(path))?;
        println!("saved schedule trace to {path}");
    }
    if let Some(dir) = args.flags.get("save") {
        std::fs::create_dir_all(dir)?;
        out.gnn.save(format!("{dir}/gnn.rlw"))?;
        out.wm.save(format!("{dir}/wm.rlw"))?;
        out.ctrl.save(format!("{dir}/ctrl.rlw"))?;
        out.trace.save(std::path::Path::new(&format!("{dir}/trace.txt")))?;
        println!("saved parameters and schedule trace to {dir}/");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("experiment id required (table1..3, fig5..10, all)"))?;
    let cfg = build_config(args)?;
    let runs: usize = args
        .flags
        .get("runs")
        .map(|r| r.parse())
        .transpose()?
        .unwrap_or(5);
    let out = args.flags.get("out").cloned().unwrap_or_else(|| "results".into());
    let backend = backend_by_name(&cfg.backend)?;
    println!("experiment backend: {}", backend.name());
    // Every experiment this process runs shares the persistent search
    // cache, so `experiment all` optimises each zoo graph once per search
    // config (`--fresh-cache` opts out).
    let ctx = ExperimentCtx::new(backend.as_ref(), cfg, out)
        .with_cache(search_cache(args))
        .with_rules(args.flags.get("rules").cloned());
    experiments::run(&ctx, id, runs)?;
    println!("{}", ctx.cache_summary());
    Ok(())
}

/// Evaluate previously trained parameters (`rlflow train --save dir`)
/// against the real environment.
fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    let dir = args
        .flags
        .get("load")
        .ok_or_else(|| anyhow::anyhow!("eval requires --load <dir> (from `rlflow train --save`)"))?;
    let backend = backend_by_name(&cfg.backend)?;
    let pipe = Pipeline::new(backend.as_ref())?;
    let graph = rlflow::zoo::by_name(&cfg.graph)?;

    let load = |family: &str| -> anyhow::Result<ParamStore> {
        let store = ParamStore::load_file(format!("{dir}/{family}.rlw"))?;
        let expected = *backend
            .manifest()
            .param_sizes
            .get(family)
            .ok_or_else(|| anyhow::anyhow!("unknown family {family}"))?;
        anyhow::ensure!(
            store.n_params() == expected,
            "{family}: saved params have {} values, backend '{}' expects {expected} \
             (were they trained on a different backend?)",
            store.n_params(),
            backend.name()
        );
        Ok(store)
    };
    let gnn = load("gnn")?;
    let wm = load("wm")?;
    let ctrl = load("ctrl")?;

    println!(
        "evaluating saved agent from {dir}/ on {} ({} runs, backend {})",
        cfg.graph,
        cfg.eval_episodes,
        backend.name()
    );
    let results = experiments::eval_pool_scores(
        &pipe,
        &cfg.env,
        cfg.device,
        &graph,
        &gnn,
        &ctrl,
        Some(&wm),
        cfg.eval_episodes,
        cfg.eval_greedy,
        cfg.seed,
    )?;
    let scores: Vec<f64> = results.iter().map(|r| r.best_improvement_pct).collect();
    let (m, s) = rlflow::util::stats::mean_std(&scores);
    let mean_step =
        results.iter().map(|r| r.mean_step_s).sum::<f64>() / results.len().max(1) as f64;
    println!(
        "eval: {:.2}% ± {:.2} improvement over {} runs ({:.1} ms/step)",
        m,
        s,
        scores.len(),
        mean_step * 1e3
    );
    Ok(())
}

/// `rlflow synth`: run the enumerative rule-synthesis pipeline and write a
/// tiered ruleset file loadable via `--rules` on optimize/experiment.
fn cmd_synth(args: &Args) -> anyhow::Result<()> {
    use rlflow::xfer::synth::{save_rules, synthesise, SynthConfig, Tier};

    let mut cfg = SynthConfig::default();
    if let Some(v) = args.flags.get("inputs") {
        cfg.n_inputs = v.parse().map_err(|e| anyhow::anyhow!("bad --inputs '{v}': {e}"))?;
    }
    if let Some(v) = args.flags.get("ops") {
        cfg.max_ops = v.parse().map_err(|e| anyhow::anyhow!("bad --ops '{v}': {e}"))?;
    }
    if let Some(v) = args.flags.get("seed") {
        cfg.seed = v.parse().map_err(|e| anyhow::anyhow!("bad --seed '{v}': {e}"))?;
    }
    if let Some(v) = args.flags.get("alphabet") {
        cfg.alphabet = v.clone();
    }
    if let Some(v) = args.flags.get("tier") {
        cfg.tier = Tier::parse(v)?;
    }
    if let Some(v) = args.flags.get("max-rules") {
        cfg.max_rules = v.parse().map_err(|e| anyhow::anyhow!("bad --max-rules '{v}': {e}"))?;
    }

    println!(
        "synthesising rules: alphabet [{}], {} inputs, up to {} ops, seed {}, tier {}",
        cfg.alphabet,
        cfg.n_inputs,
        cfg.max_ops,
        cfg.seed,
        cfg.tier.as_str()
    );
    let out = synthesise(&cfg)?;
    let s = &out.stats;
    println!(
        "enumerated {} graphs, {} fingerprint groups, {} candidate pairs",
        s.enumerated, s.groups, s.candidates
    );
    println!(
        "pruned: {} renamings, {} common-subgraph; verified {} (rejected {})",
        s.pruned_renaming, s.pruned_common, s.verified, s.rejected
    );
    println!(
        "tiers: {} always-safe, {} shape-preserving, {} all",
        s.tier_always_safe, s.tier_shape_preserving, s.tier_all
    );
    println!("kept {} rules at tier <= {}:", out.rules.len(), cfg.tier.as_str());
    for r in &out.rules {
        println!(
            "  {:<24} {:<16} {} -> {} ops{}",
            r.name(),
            r.tier().as_str(),
            r.lhs().n_ops(),
            r.rhs().n_ops(),
            if r.shape_generic() { "" } else { " (square-only)" }
        );
    }
    if let Some(path) = args.flags.get("out") {
        save_rules(path, &out.rules, &cfg)?;
        println!("wrote ruleset to {path}");
    } else {
        println!("(no --out given; ruleset not saved)");
    }
    Ok(())
}

fn cmd_generate_rules(args: &Args) -> anyhow::Result<()> {
    let n_inputs: usize = args.flags.get("inputs").map(|v| v.parse()).transpose()?.unwrap_or(2);
    let max_ops: usize = args.flags.get("ops").map(|v| v.parse()).transpose()?.unwrap_or(2);
    let (cands, stats) = rlflow::xfer::generator::generate(n_inputs, max_ops, 42);
    println!(
        "enumerated {} graphs, {} fingerprint groups, {} candidate pairs",
        stats.enumerated, stats.groups, stats.candidates
    );
    println!(
        "pruned: {} renamings, {} common-subgraph; verified: {}",
        stats.pruned_renaming, stats.pruned_common, stats.verified
    );
    for c in cands.iter().filter(|c| c.verified).take(10) {
        println!("--- verified substitution ---\nLHS:\n{}RHS:\n{}", c.lhs, c.rhs);
    }
    if args.flags.get("verify").map(|v| v == "true").unwrap_or(false) {
        let lib = standard_library();
        let graphs: Vec<rlflow::graph::Graph> = vec![rlflow::zoo::squeezenet1_1()];
        println!("verifying curated library on SqueezeNet (interpreter)...");
        let report = rlflow::xfer::generator::verify_library(&lib, &graphs, 11)?;
        for (name, sites) in report {
            println!("  {:<24} {} sites OK", name, sites);
        }
    }
    Ok(())
}

const DEFAULT_SERVE_ADDR: &str = "127.0.0.1:7777";

fn usize_flag(args: &Args, name: &str, default: usize) -> anyhow::Result<usize> {
    match args.flags.get(name) {
        Some(v) => v.parse().map_err(|e| anyhow::anyhow!("bad --{name} '{v}': {e}")),
        None => Ok(default),
    }
}

/// `rlflow serve`: run the optimisation daemon in the foreground until a
/// `shutdown` control request drains it.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use rlflow::serve::ServerConfig;
    let addr = args
        .flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| DEFAULT_SERVE_ADDR.to_string());
    let mut cfg = ServerConfig::new(addr);
    cfg.workers = usize_flag(args, "workers", cfg.workers)?;
    cfg.queue_cap = usize_flag(args, "queue", cfg.queue_cap)?;
    cfg.default_timeout_ms =
        usize_flag(args, "timeout-ms", cfg.default_timeout_ms as usize)? as u64;
    cfg.core.threads = usize_flag(args, "threads", cfg.core.threads)?;
    cfg.core.snapshot_every = usize_flag(args, "snapshot-every", cfg.core.snapshot_every)?;
    cfg.core.max_results = usize_flag(args, "max-results", cfg.core.max_results)?;
    if let Some(dir) = args.flags.get("cache-dir") {
        cfg.core.cache_dir = Some(std::path::PathBuf::from(dir));
    }
    rlflow::serve::run(cfg)
}

/// `rlflow request`: one-shot client for the daemon — submit a graph for
/// optimisation, or send a `stats`/`ping`/`shutdown` control request.
fn cmd_request(args: &Args) -> anyhow::Result<()> {
    use rlflow::serve::{client, encode_control, encode_optimize, Method, OptimizeRequest, Response};
    let addr = args
        .flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| DEFAULT_SERVE_ADDR.to_string());
    let flag = |name: &str| args.flags.get(name).map(|v| v == "true").unwrap_or(false);

    if flag("ping") || flag("stats") || flag("shutdown") {
        let kind = if flag("ping") {
            "ping"
        } else if flag("stats") {
            "stats"
        } else {
            "shutdown"
        };
        let resp = client::roundtrip(&addr, &encode_control(kind), client::DEFAULT_READ_TIMEOUT)?;
        return match resp {
            Response::Pong => {
                println!("pong");
                Ok(())
            }
            Response::Stats(stats) => {
                println!("{}", stats.to_string_pretty());
                Ok(())
            }
            Response::Ok(detail) => {
                println!("ok: {detail}");
                Ok(())
            }
            Response::Error { code, message } => {
                anyhow::bail!("server error ({}): {message}", code.as_str())
            }
            Response::Result { .. } => anyhow::bail!("unexpected result for a control request"),
        };
    }

    // An optimise request: a zoo graph by name or an imported model file.
    let (graph, name) = if let Some(path) = args.flags.get("import") {
        let graph = rlflow::graph::onnx::load(path)?;
        let stem = std::path::Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "imported".to_string());
        (graph, stem)
    } else {
        let name = args
            .flags
            .get("graph")
            .cloned()
            .ok_or_else(|| anyhow::anyhow!(
                "request needs --graph <zoo name>, --import <model.json>, or a control flag \
                 (--stats/--ping/--shutdown)"
            ))?;
        (rlflow::zoo::by_name(&name)?, name)
    };
    let method = match args.flags.get("method").map(String::as_str).unwrap_or("taso") {
        "greedy" => Method::Greedy { max_steps: usize_flag(args, "max-steps", 100)? },
        "taso" => {
            let alpha = match args.flags.get("alpha") {
                Some(v) => v.parse().map_err(|e| anyhow::anyhow!("bad --alpha '{v}': {e}"))?,
                None => 1.05,
            };
            Method::Taso {
                alpha,
                beam: usize_flag(args, "beam", 4)?,
                depth: usize_flag(args, "depth", 80)?,
            }
        }
        m => anyhow::bail!("unknown method '{m}' (greedy|taso)"),
    };
    let timeout_ms = match args.flags.get("timeout-ms") {
        Some(v) => Some(
            v.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad --timeout-ms '{v}': {e}"))?,
        ),
        None => None,
    };
    let req = OptimizeRequest {
        graph,
        graph_name: name.clone(),
        method,
        cost_noise: 0.0,
        noise_seed: 0,
        timeout_ms,
    };
    // Give the daemon's own budget room to produce its typed `timeout`
    // response before the client-side read deadline fires.
    let read_timeout = match timeout_ms {
        Some(t) => std::time::Duration::from_millis(t.saturating_add(30_000)),
        None => client::DEFAULT_READ_TIMEOUT,
    };
    // `--retries N`: retry transient failures (overloaded/timeout and
    // transport errors) with seeded-jitter exponential backoff, bounded
    // by `--retry-budget-ms`. Fatal errors (bad_request) never retry.
    let retry = client::RetryCfg {
        retries: usize_flag(args, "retries", 0)?,
        budget_ms: usize_flag(args, "retry-budget-ms", 10_000)? as u64,
        seed: usize_flag(args, "retry-seed", 0)? as u64,
    };
    let (resp, attempts) =
        client::roundtrip_retry(&addr, &encode_optimize(&req)?, read_timeout, &retry)?;
    match resp {
        Response::Result { payload, provenance, elapsed_s } => {
            println!("provenance: {} (attempt {attempts})", provenance.as_str());
            println!(
                "{name}: {:.3} ms -> {:.3} ms ({:.1}% better) in {:.2}s server-side, {} graphs explored",
                payload.get("initial_ms")?.as_f64()?,
                payload.get("final_ms")?.as_f64()?,
                payload.get("improvement_pct")?.as_f64()?,
                elapsed_s,
                payload.get("graphs_explored")?.as_usize()?,
            );
            for step in payload.get("steps")?.as_arr()? {
                let pair = step.as_arr()?;
                anyhow::ensure!(pair.len() == 2, "malformed step in response");
                println!("  applied {:<22} -> {:.3} ms", pair[0].as_str()?, pair[1].as_f64()?);
            }
            if let Some(path) = args.flags.get("export") {
                std::fs::write(path, payload.get("graph")?.to_string_pretty())?;
                println!("exported optimised graph to {path}");
            }
            Ok(())
        }
        Response::Error { code, message } => {
            anyhow::bail!("server error ({}): {message}", code.as_str())
        }
        other => anyhow::bail!("unexpected response: {other:?}"),
    }
}
