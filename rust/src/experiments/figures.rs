//! Figure reproductions: Fig. 5 (reward functions), Fig. 6 (runtime vs
//! baselines), Fig. 7 (optimisation time), Fig. 8 (WM loss curves),
//! Fig. 9 (dream rewards), Fig. 10 (transformation heatmap).

use std::collections::HashMap;

use crate::coordinator::Pipeline;
use crate::csv_row;
use crate::env::{Env, RewardKind};
use crate::runtime::ParamStore;
use crate::search::{greedy_optimise_cached, taso_optimise_cached, TasoConfig};
use crate::util::csv::CsvWriter;
use crate::util::stats::{ci95, mean, minmax_normalise};
use crate::util::Rng;
use crate::xfer::library::standard_library;

use super::{eval_agent, train_model_based, ExperimentCtx};

/// **Fig. 5**: model-free agent on BERT under reward functions R1–R5;
/// normalised reward per training iteration.
pub fn fig5(ctx: &ExperimentCtx) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let graph = crate::zoo::bert_base();
    let rules = standard_library();
    let presets = ["r1", "r2", "r3", "r4", "r5"];

    let mut w = CsvWriter::create(
        ctx.out("fig5.csv"),
        &["reward_fn", "iteration", "reward", "reward_norm"],
    )?;
    println!("\nFig. 5: reward-function comparison (model-free, BERT)");
    for preset in presets {
        let mut cfg = ctx.cfg.clone();
        cfg.env.reward = RewardKind::preset(preset)?;
        let cost = ctx.cost_model();
        let mut env = Env::new(graph.clone(), &rules, &cost, cfg.env.clone());
        let gnn = ParamStore::init(ctx.backend, "gnn", cfg.seed as i32)?;
        let mut ctrl = ParamStore::init(ctx.backend, "ctrl", cfg.seed as i32 + 10)?;
        let mut rng = Rng::new(cfg.seed ^ preset.len() as u64);
        let mut curve = Vec::with_capacity(cfg.free_iterations);
        for _ in 0..cfg.free_iterations {
            let (mean_reward, _) = pipe.model_free_iteration(
                &gnn,
                &mut ctrl,
                &mut env,
                cfg.free_episodes_per_iter,
                &cfg.ppo,
                &mut rng,
            )?;
            curve.push(mean_reward as f64);
        }
        let norm = minmax_normalise(&curve);
        for (i, (&r, &n)) in curve.iter().zip(&norm).enumerate() {
            csv_row!(w; preset, i, format!("{r:.4}"), format!("{n:.4}"))?;
        }
        println!(
            "  {}: first {:.2} -> last {:.2} (mean {:.2})",
            preset,
            curve.first().unwrap_or(&0.0),
            curve.last().unwrap_or(&0.0),
            mean(&curve)
        );
    }
    w.flush()
}

/// **Fig. 6**: relative runtime improvement per graph for TF-greedy, TASO,
/// model-free RL and model-based RLFlow (mean ± 95% CI over `runs`).
pub fn fig6(ctx: &ExperimentCtx, runs: usize) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let rules = standard_library();
    let cost = ctx.cost_model();
    let mut w = CsvWriter::create(
        ctx.out("fig6.csv"),
        &["graph", "method", "improvement_pct_mean", "ci95"],
    )?;
    println!("\nFig. 6: runtime improvement of optimised graphs (%)");
    println!("{:<15} {:>10} {:>10} {:>12} {:>12}", "Graph", "TF", "TASO", "ModelFree", "RLFlow");
    for (info, g) in crate::zoo::all() {
        // Deterministic baselines (memoised across the context).
        let (_, tf_log) = greedy_optimise_cached(&g, &rules, &cost, 50, 0, &ctx.search_cache);
        let (_, taso_log) =
            taso_optimise_cached(&g, &rules, &cost, &TasoConfig::default(), &ctx.search_cache);

        // Model-free PPO agent trained in the real environment.
        let mut free_scores = Vec::new();
        {
            let mut cfg = ctx.cfg.clone();
            let gnn = ParamStore::init(ctx.backend, "gnn", cfg.seed as i32)?;
            let mut ctrl = ParamStore::init(ctx.backend, "ctrl", cfg.seed as i32 + 20)?;
            let mut rng = Rng::new(cfg.seed + 100);
            let mut env = Env::new(g.clone(), &rules, &cost, cfg.env.clone());
            for _ in 0..cfg.free_iterations {
                pipe.model_free_iteration(
                    &gnn,
                    &mut ctrl,
                    &mut env,
                    cfg.free_episodes_per_iter,
                    &cfg.ppo,
                    &mut rng,
                )?;
            }
            // Pooled model-free evaluation: `runs` episodes per pass.
            let results = super::eval_pool_scores(
                &pipe,
                &cfg.env,
                cfg.device,
                &g,
                &gnn,
                &ctrl,
                None,
                runs,
                cfg.eval_greedy,
                cfg.seed + 200,
            )?;
            free_scores.extend(results.iter().map(|r| r.best_improvement_pct));
            cfg.graph = info.name.to_string();
        }

        // Model-based RLFlow.
        let agent = train_model_based(&pipe, &ctx.cfg, &g, ctx.cfg.seed)?;
        let (rl_scores, _, _) = eval_agent(&pipe, &ctx.cfg, &agent, &g, runs, ctx.cfg.seed)?;

        let rows = [
            ("tensorflow", vec![tf_log.improvement_pct()]),
            ("taso", vec![taso_log.improvement_pct()]),
            ("model_free", free_scores),
            ("rlflow", rl_scores),
        ];
        print!("{:<15}", info.name);
        for (method, scores) in &rows {
            let m = mean(scores);
            let ci = ci95(scores);
            print!(" {:>9.1}%", m);
            csv_row!(w; info.name, method, format!("{m:.3}"), format!("{ci:.3}"))?;
        }
        println!();
    }
    w.flush()
}

/// **Fig. 7**: wall-clock time to produce the optimised graph — trained
/// RLFlow agent rollout vs TASO search. The search columns deliberately
/// time *uncached* runs (this figure measures search, and fig6 sharing the
/// context cache must not turn it into lookup timings); the results are
/// stored back into the shared cache afterwards, and `taso_warm_s` reports
/// the persistent-cache repeat for the same (graph, config).
pub fn fig7(ctx: &ExperimentCtx, runs: usize) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let rules = standard_library();
    let cost = ctx.cost_model();
    let mut w = CsvWriter::create(
        ctx.out("fig7.csv"),
        &["graph", "rlflow_s", "taso_s", "greedy_s", "taso_warm_s"],
    )?;
    println!("\nFig. 7: optimisation time (s)");
    println!(
        "{:<15} {:>10} {:>10} {:>10} {:>12}",
        "Graph", "RLFlow", "TASO", "Greedy", "TASO warm"
    );
    let taso_cfg = TasoConfig::default();
    for (info, g) in crate::zoo::all() {
        let t0 = std::time::Instant::now();
        let (taso_g, taso_log) = crate::search::taso_optimise(&g, &rules, &cost, &taso_cfg);
        let taso_s = t0.elapsed().as_secs_f64();
        ctx.search_cache.store(
            crate::search::taso_fingerprint(&cost, &rules, &taso_cfg),
            &g,
            &taso_g,
            &taso_log,
        );

        let t0 = std::time::Instant::now();
        let (greedy_g, greedy_log) = crate::search::greedy_optimise(&g, &rules, &cost, 50);
        let greedy_s = t0.elapsed().as_secs_f64();
        ctx.search_cache.store(
            crate::search::greedy_fingerprint(&cost, &rules, 50),
            &g,
            &greedy_g,
            &greedy_log,
        );

        // Warm repeat: guaranteed result-memo hit, bit-identical output.
        let t0 = std::time::Instant::now();
        let (_, warm_log) =
            taso_optimise_cached(&g, &rules, &cost, &taso_cfg, &ctx.search_cache);
        let taso_warm_s = t0.elapsed().as_secs_f64();
        debug_assert!(warm_log.from_cache, "warm repeat must be a lookup");
        let _ = warm_log;

        // RLFlow: agent rollout only (paper: "does not include the time
        // needed to learn the world model, nor training the controller").
        let agent = train_model_based(&pipe, &ctx.cfg, &g, ctx.cfg.seed)?;
        let t0 = std::time::Instant::now();
        let (_, _, _mean_step) = eval_agent(&pipe, &ctx.cfg, &agent, &g, runs, ctx.cfg.seed)?;
        let rlflow_s = t0.elapsed().as_secs_f64() / runs as f64;

        println!(
            "{:<15} {:>10.3} {:>10.3} {:>10.3} {:>12.5}",
            info.name, rlflow_s, taso_s, greedy_s, taso_warm_s
        );
        csv_row!(w; info.name, format!("{rlflow_s:.4}"), format!("{taso_s:.4}"), format!("{greedy_s:.4}"), format!("{taso_warm_s:.6}"))?;
    }
    println!("{}", ctx.cache_summary());
    w.flush()
}

/// **Fig. 8**: world-model log-likelihood loss during training, per graph.
pub fn fig8(ctx: &ExperimentCtx) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let mut w = CsvWriter::create(
        ctx.out("fig8.csv"),
        &["graph", "step", "total", "nll", "reward_mse", "mask_bce", "done_bce"],
    )?;
    println!("\nFig. 8: world-model training loss per graph");
    for (info, g) in crate::zoo::all() {
        let agent = train_model_based(&pipe, &ctx.cfg, &g, ctx.cfg.seed)?;
        for (i, l) in agent.wm_curve.iter().enumerate() {
            csv_row!(w; info.name, i, format!("{:.5}", l.total), format!("{:.5}", l.nll), format!("{:.5}", l.reward_mse), format!("{:.5}", l.mask_bce), format!("{:.5}", l.done_bce))?;
        }
        let first = agent.wm_curve.first().map(|l| l.total).unwrap_or(0.0);
        let last = agent.wm_curve.last().map(|l| l.total).unwrap_or(0.0);
        println!(
            "  {:<15} loss {:.3} -> {:.3} over {} steps",
            info.name,
            first,
            last,
            agent.wm_curve.len()
        );
    }
    w.flush()
}

/// **Fig. 9**: predicted (dream) reward per epoch while training the
/// controller inside the world model, min-max normalised per graph.
pub fn fig9(ctx: &ExperimentCtx) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let mut w =
        CsvWriter::create(ctx.out("fig9.csv"), &["graph", "epoch", "reward", "reward_norm"])?;
    println!("\nFig. 9: predicted reward inside the dream per graph");
    for (info, g) in crate::zoo::all() {
        let agent = train_model_based(&pipe, &ctx.cfg, &g, ctx.cfg.seed)?;
        let curve: Vec<f64> = agent.dream_curve.iter().map(|&r| r as f64).collect();
        let norm = minmax_normalise(&curve);
        for (i, (&r, &nrm)) in curve.iter().zip(&norm).enumerate() {
            csv_row!(w; info.name, i, format!("{r:.4}"), format!("{nrm:.4}"))?;
        }
        println!(
            "  {:<15} dream reward {:.2} -> {:.2}",
            info.name,
            curve.first().unwrap_or(&0.0),
            curve.last().unwrap_or(&0.0)
        );
    }
    w.flush()
}

/// **Fig. 10**: heatmap of transformations applied by the trained agent
/// during evaluation (rule name x graph -> count).
pub fn fig10(ctx: &ExperimentCtx) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let rules = standard_library();
    let mut w = CsvWriter::create(ctx.out("fig10.csv"), &["graph", "rule", "count"])?;
    println!("\nFig. 10: transformations applied by the trained controller");
    let mut any_counts: HashMap<&'static str, usize> = HashMap::new();
    for (info, g) in crate::zoo::all() {
        let agent = train_model_based(&pipe, &ctx.cfg, &g, ctx.cfg.seed)?;
        let (_, history, _) = eval_agent(&pipe, &ctx.cfg, &agent, &g, 3, ctx.cfg.seed)?;
        let mut counts: HashMap<usize, usize> = HashMap::new();
        for (xfer, _) in history {
            *counts.entry(xfer).or_default() += 1;
        }
        let mut named: Vec<(&'static str, usize)> = counts
            .into_iter()
            .filter_map(|(x, c)| rules.get(x).map(|r| (r.name(), c)))
            .collect();
        named.sort_by(|a, b| b.1.cmp(&a.1));
        print!("  {:<15}", info.name);
        for (name, c) in &named {
            print!(" {}x{}", name, c);
            *any_counts.entry(name).or_default() += c;
            csv_row!(w; info.name, name, c)?;
        }
        println!();
    }
    w.flush()
}
