//! Table reproductions: Table 1 (graph properties), Table 2 (inference
//! time + memory improvement), Table 3 (temperature sweep).

use crate::csv_row;
use crate::search::greedy_optimise_cached;
use crate::util::csv::CsvWriter;
use crate::util::stats::mean_std;
use crate::util::Rng;
use crate::xfer::library::standard_library;

use super::{eval_agent, train_model_based, ExperimentCtx};

/// **Table 1**: properties of the six evaluation graphs. "Substitutions"
/// counts applicable rule sites on the unmodified graph (the paper's
/// column counts TASO's applicable substitutions the same way).
pub fn table1(ctx: &ExperimentCtx) -> anyhow::Result<()> {
    let rules = ctx.search_rules()?;
    let mut w = CsvWriter::create(
        ctx.out("table1.csv"),
        &["graph", "type", "layers", "unique_layers", "ops", "substitutions"],
    )?;
    println!("\nTable 1: properties of the evaluation graphs");
    println!(
        "{:<15} {:<14} {:>6} {:>7} {:>6} {:>14}",
        "Graph", "Type", "Layers", "Unique", "Ops", "Substitutions"
    );
    for (info, g) in crate::zoo::all() {
        let subs = rules.count_matches(&g);
        println!(
            "{:<15} {:<14} {:>6} {:>7} {:>6} {:>14}",
            info.name, info.family, info.layers, info.unique_layers, g.n_ops(), subs
        );
        csv_row!(w; info.name, info.family, info.layers, info.unique_layers, g.n_ops(), subs)?;
    }
    w.flush()
}

/// **Table 2**: inference time (ms) and memory (GiB) of the TF-optimised
/// baseline, and RLFlow's percentage improvement on both at tau = 1.0.
pub fn table2(ctx: &ExperimentCtx, runs: usize) -> anyhow::Result<()> {
    let pipe = crate::coordinator::Pipeline::new(ctx.backend)?;
    // Greedy baseline uses the (possibly `--rules`-extended) search
    // vocabulary; the RL environment below keeps the plain handwritten
    // library so the agent's action space stays fixed.
    let search_vocab = ctx.search_rules()?;
    let rules = standard_library();
    let cost = ctx.cost_model();
    let mut cfg = ctx.cfg.clone();
    cfg.temperature = 1.0;

    let mut w = CsvWriter::create(
        ctx.out("table2.csv"),
        &["graph", "tf_ms", "tf_gib", "rlflow_time_impr_pct", "rlflow_mem_impr_pct"],
    )?;
    println!("\nTable 2: improvement vs TensorFlow-style baseline (tau=1.0)");
    println!(
        "{:<15} {:>10} {:>10} {:>12} {:>12}",
        "Graph", "Inf (ms)", "Mem (GiB)", "%t impr", "%m impr"
    );
    for (info, g) in crate::zoo::all() {
        // "TensorFlow" baseline: greedy rule application (memoised across
        // the context — fig6/suite optimise the same graphs).
        let (tf_graph, _) =
            greedy_optimise_cached(&g, &search_vocab, &cost, 50, 0, &ctx.search_cache);
        let tf_ms = cost.graph_runtime_ms(&tf_graph);
        let tf_gib = cost.graph_memory_gib(&tf_graph);

        let agent = train_model_based(&pipe, &cfg, &g, cfg.seed)?;
        let (imps, _, _) = eval_agent(&pipe, &cfg, &agent, &g, runs, cfg.seed)?;
        // Best run's graph improvement relative to the *raw* graph; convert
        // to a ratio against the TF baseline for the table.
        let raw_ms = cost.graph_runtime_ms(&g);
        let best = imps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let rl_ms = raw_ms * (1.0 - best / 100.0);
        let t_impr = 100.0 * (tf_ms - rl_ms) / tf_ms;

        // Memory: evaluate the best graph directly.
        let mut rng = Rng::new(cfg.seed);
        let mut env = crate::env::Env::new(g.clone(), &rules, &cost, cfg.env.clone());
        let res =
            pipe.eval_real(&agent.gnn, &agent.ctrl, Some(&agent.wm), &mut env, true, &mut rng)?;
        let rl_gib = res
            .best_graph
            .as_ref()
            .map(|bg| cost.graph_memory_gib(bg))
            .unwrap_or(tf_gib);
        let m_impr = 100.0 * (tf_gib - rl_gib) / tf_gib;

        println!(
            "{:<15} {:>10.2} {:>10.3} {:>11.1}% {:>11.1}%",
            info.name, tf_ms, tf_gib, t_impr, m_impr
        );
        csv_row!(w; info.name, format!("{tf_ms:.4}"), format!("{tf_gib:.5}"), format!("{t_impr:.2}"), format!("{m_impr:.2}"))?;
    }
    w.flush()
}

/// **Table 3**: temperature sweep on BERT — world-model (dream) score vs
/// real-environment score, `runs` evaluations each.
pub fn table3(ctx: &ExperimentCtx, runs: usize) -> anyhow::Result<()> {
    let pipe = crate::coordinator::Pipeline::new(ctx.backend)?;
    let graph = crate::zoo::bert_base();
    let temps = [0.1f32, 0.5, 0.75, 1.0, 1.2, 1.5, 1.75, 2.0, 2.5, 3.0];

    let mut w = CsvWriter::create(
        ctx.out("table3.csv"),
        &["temperature", "wm_score_mean", "wm_score_std", "real_score_mean", "real_score_std"],
    )?;
    println!("\nTable 3: temperature sweep (BERT)");
    println!("{:>6} {:>18} {:>18}", "tau", "WM score", "Real score");
    for &tau in &temps {
        let mut cfg = ctx.cfg.clone();
        cfg.temperature = tau;
        let agent = train_model_based(&pipe, &cfg, &graph, cfg.seed ^ (tau.to_bits() as u64))?;
        // WM score: mean predicted reward over the tail of dream training,
        // interpreted as % improvement (rewards are % units).
        let tail = &agent.dream_curve[agent.dream_curve.len().saturating_sub(5)..];
        let wm_scores: Vec<f64> = tail.iter().map(|&r| r as f64).collect();
        let (wm_mean, wm_std) = mean_std(&wm_scores);
        let (real_scores, _, _) = eval_agent(&pipe, &cfg, &agent, &graph, runs, cfg.seed)?;
        let (real_mean, real_std) = mean_std(&real_scores);
        println!(
            "{:>6.2} {:>9.2}% ± {:>5.2} {:>9.2}% ± {:>5.2}",
            tau, wm_mean, wm_std, real_mean, real_std
        );
        csv_row!(w; tau, format!("{wm_mean:.3}"), format!("{wm_std:.3}"), format!("{real_mean:.3}"), format!("{real_std:.3}"))?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    // table1 needs no engine; exercise it through a lightweight ctx-free path.
    use crate::xfer::library::standard_library;

    #[test]
    fn substitution_counts_nonzero_for_all_graphs() {
        let rules = standard_library();
        for (info, g) in crate::zoo::all() {
            let subs = rules.count_matches(&g);
            assert!(subs > 10, "{}: only {} substitutions", info.name, subs);
        }
    }

    #[test]
    fn transformers_have_addln_sites_cnns_have_conv_sites() {
        let rules = standard_library();
        let addln = rules.index_of("fuse_add_ln").unwrap();
        let conv_relu = rules.index_of("fuse_conv_relu").unwrap();
        let bert = crate::zoo::bert_base();
        let resnet = crate::zoo::resnet18();
        assert!(!rules.get(addln).unwrap().find(&bert).is_empty());
        assert!(rules.get(addln).unwrap().find(&resnet).is_empty());
        assert!(
            !rules.get(conv_relu).unwrap().find(&resnet).is_empty()
                || rules.get(conv_relu).unwrap().find(&bert).is_empty()
        );
    }
}
