//! Experiment drivers: one per table and figure in the paper's evaluation
//! (§4). Each regenerates the corresponding result as a CSV under
//! `results/` plus a human-readable table on stdout — the DESIGN.md
//! experiment index maps each ID to the modules it exercises.

pub mod figures;
pub mod suite;
pub mod tables;

use std::path::PathBuf;
use std::sync::Arc;

use crate::agent::Episode;
use crate::config::RunConfig;
use crate::coordinator::{collect_random_parallel, Pipeline};
use crate::cost::CostModel;
use crate::graph::Graph;
use crate::runtime::{Backend, ParamStore};
use crate::search::SearchCache;
use crate::util::Rng;
use crate::wm::WmLosses;

/// Everything an experiment driver needs: the model-execution backend, the
/// resolved run configuration, the output directory, and the persistent
/// search cache shared across every deterministic baseline the context runs.
pub struct ExperimentCtx<'e> {
    /// Model-execution backend (host / pjrt / auto).
    pub backend: &'e dyn Backend,
    /// Resolved run configuration.
    pub cfg: RunConfig,
    /// Directory the CSV outputs land in.
    pub out_dir: PathBuf,
    /// Cross-run search memoisation: every `greedy`/`taso` baseline of
    /// every figure/table this context runs shares it, so `experiment all`
    /// (and repeated runs within one process, via
    /// [`ExperimentCtx::with_cache`] + `search::memo::global`) re-optimises
    /// each zoo graph exactly once per search config.
    pub search_cache: Arc<SearchCache>,
    /// Optional synthesised-ruleset file (`rlflow synth` output) appended to
    /// the handwritten library for the deterministic search baselines. The
    /// RL environments keep the plain [`standard_library`] so the agent's
    /// fixed xfer action space is unaffected.
    ///
    /// [`standard_library`]: crate::xfer::library::standard_library
    pub rules_path: Option<String>,
}

impl<'e> ExperimentCtx<'e> {
    /// A context with a fresh private [`SearchCache`].
    pub fn new(backend: &'e dyn Backend, cfg: RunConfig, out_dir: impl Into<PathBuf>) -> Self {
        let out_dir = out_dir.into();
        let _ = std::fs::create_dir_all(&out_dir);
        Self { backend, cfg, out_dir, search_cache: Arc::new(SearchCache::new()), rules_path: None }
    }

    /// Share an existing cache (the CLI passes `search::memo::global()`
    /// unless `--fresh-cache` is given).
    pub fn with_cache(mut self, cache: Arc<SearchCache>) -> Self {
        self.search_cache = cache;
        self
    }

    /// Load the deterministic search baselines' rules from a synthesised
    /// ruleset file on top of the handwritten library (`--rules` on the
    /// `experiment` subcommand).
    pub fn with_rules(mut self, rules_path: Option<String>) -> Self {
        self.rules_path = rules_path;
        self
    }

    /// The rule vocabulary the deterministic search baselines run with:
    /// the handwritten library, extended by [`ExperimentCtx::rules_path`]
    /// when one was given. The combined set has its own
    /// [`RuleSet::fingerprint`](crate::xfer::RuleSet::fingerprint), so
    /// cached searches never alias across vocabularies.
    pub fn search_rules(&self) -> anyhow::Result<crate::xfer::RuleSet> {
        crate::xfer::synth::library_with_rules(self.rules_path.as_deref())
    }

    /// Path of one output file inside the context's output directory.
    pub fn out(&self, file: &str) -> PathBuf {
        self.out_dir.join(file)
    }

    /// Cost model for the deterministic baselines and environments: the
    /// configured device profile, with the §3.1.4 measurement-noise field
    /// layered on when `cfg.cost_noise > 0` (see [`RunConfig::cost_model`];
    /// noisy experiments replay bit-for-bit — and still cache, since the
    /// noise configuration is part of the search-config fingerprint).
    pub fn cost_model(&self) -> CostModel {
        self.cfg.cost_model()
    }

    /// One-line hit/miss/evict summary of the shared search cache, for the
    /// experiment drivers' stdout reports.
    pub fn cache_summary(&self) -> String {
        format!("search cache: {}", self.search_cache.stats())
    }
}

/// Everything the model-based training pipeline produces for one graph.
pub struct TrainedAgent {
    pub gnn: ParamStore,
    pub wm: ParamStore,
    pub ctrl: ParamStore,
    pub ae_losses: Vec<f32>,
    pub wm_curve: Vec<WmLosses>,
    pub dream_curve: Vec<f32>,
    pub episodes: Vec<Episode>,
    /// Wall-clock seconds spent in each stage.
    pub stage_seconds: Vec<(&'static str, f64)>,
}

/// Run the full model-based pipeline (collect -> AE -> encode -> WM ->
/// dream controller) on one graph. The shared engine of Fig. 6/8/9/10 and
/// Tables 2/3.
pub fn train_model_based(
    pipe: &Pipeline,
    cfg: &RunConfig,
    graph: &Graph,
    seed: u64,
) -> anyhow::Result<TrainedAgent> {
    let mut rng = Rng::new(seed);
    let mut stage_seconds = Vec::new();
    let timed = |stage: &'static str, out: &mut Vec<(&'static str, f64)>, t0: std::time::Instant| {
        out.push((stage, t0.elapsed().as_secs_f64()));
    };

    let t0 = std::time::Instant::now();
    let mut episodes = collect_random_parallel(
        graph,
        &cfg.env,
        cfg.device,
        (pipe.encoder.max_nodes, pipe.encoder.n_feats),
        pipe.dims.x1,
        cfg.collect_episodes,
        cfg.collect_noop_prob,
        // n_envs comes from `envs` alone: collect_workers is a pure
        // performance knob and must never change the collected episodes.
        cfg.envs,
        cfg.collect_workers,
        seed,
    );
    timed("collect", &mut stage_seconds, t0);

    let t0 = std::time::Instant::now();
    let mut gnn = ParamStore::init(pipe.backend, "gnn", seed as i32)?;
    let ae_losses = pipe.train_gnn_ae(&mut gnn, &episodes, cfg.ae_steps, cfg.ae_lr, &mut rng)?;
    timed("gnn_ae", &mut stage_seconds, t0);

    let t0 = std::time::Instant::now();
    pipe.encode_episodes(&gnn, &mut episodes)?;
    timed("encode", &mut stage_seconds, t0);

    let t0 = std::time::Instant::now();
    let mut wm = ParamStore::init(pipe.backend, "wm", seed as i32 + 1)?;
    let wm_curve = pipe.train_wm(&mut wm, &episodes, &cfg.wm, &mut rng)?;
    timed("wm", &mut stage_seconds, t0);

    let t0 = std::time::Instant::now();
    let mut ctrl = ParamStore::init(pipe.backend, "ctrl", seed as i32 + 2)?;
    let dream_curve = pipe.train_controller_dream(
        &mut ctrl,
        &wm,
        &episodes,
        cfg.dream_epochs,
        cfg.dream_horizon,
        cfg.temperature,
        cfg.wm.reward_scale,
        &cfg.ppo,
        &mut rng,
    )?;
    timed("dream_ctrl", &mut stage_seconds, t0);

    Ok(TrainedAgent { gnn, wm, ctrl, ae_losses, wm_curve, dream_curve, episodes, stage_seconds })
}

/// Build a `runs`-wide deterministic [`crate::env::EnvPool`] on `graph`
/// and run one batched evaluation pass — the single place eval pools are
/// configured (eval_agent, fig6's model-free bars, the suite and the
/// table 3 sweep all route through here).
#[allow(clippy::too_many_arguments)]
pub fn eval_pool_scores(
    pipe: &Pipeline,
    env_cfg: &crate::env::EnvConfig,
    device: crate::cost::DeviceProfile,
    graph: &Graph,
    gnn: &crate::runtime::ParamStore,
    ctrl: &crate::runtime::ParamStore,
    wm: Option<&crate::runtime::ParamStore>,
    runs: usize,
    greedy: bool,
    seed: u64,
) -> anyhow::Result<Vec<crate::coordinator::EvalResult>> {
    let cost = CostModel::new(device);
    let mut pool = crate::env::EnvPool::new(
        graph,
        crate::xfer::library::standard_library(),
        &cost,
        &crate::env::EnvPoolConfig {
            n_envs: runs.max(1),
            env: env_cfg.clone(),
            threads: 0,
            seed,
            noise_std: 0.0,
        },
    );
    let mut rng = Rng::new(seed);
    pipe.eval_real_pool(gnn, ctrl, wm, &mut pool, greedy, &mut rng)
}

/// Evaluate a trained agent `runs` times; returns per-run best
/// improvements (%) and the merged action history. The `runs` episodes
/// run as one [`crate::env::EnvPool`] batch — B episodes per pass instead
/// of one.
pub fn eval_agent(
    pipe: &Pipeline,
    cfg: &RunConfig,
    agent: &TrainedAgent,
    graph: &Graph,
    runs: usize,
    seed: u64,
) -> anyhow::Result<(Vec<f64>, Vec<(usize, usize)>, f64)> {
    let results = eval_pool_scores(
        pipe,
        &cfg.env,
        cfg.device,
        graph,
        &agent.gnn,
        &agent.ctrl,
        Some(&agent.wm),
        runs,
        cfg.eval_greedy,
        seed,
    )?;
    let improvements = results.iter().map(|r| r.best_improvement_pct).collect();
    let history = results.iter().flat_map(|r| r.history.iter().copied()).collect();
    let mean_step =
        results.iter().map(|r| r.mean_step_s).sum::<f64>() / results.len().max(1) as f64;
    Ok((improvements, history, mean_step))
}

/// Dispatch an experiment by paper id.
pub fn run(ctx: &ExperimentCtx, id: &str, runs: usize) -> anyhow::Result<()> {
    match id {
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx, runs),
        "table3" => tables::table3(ctx, runs),
        "fig5" => figures::fig5(ctx),
        "fig6" => figures::fig6(ctx, runs),
        "fig7" => figures::fig7(ctx, runs),
        "fig8" => figures::fig8(ctx),
        "fig9" => figures::fig9(ctx),
        "fig10" => figures::fig10(ctx),
        "suite" => suite::suite(ctx, runs),
        "table3shared" => suite::table3_shared(
            ctx,
            runs,
            &[0.1, 0.5, 1.0, 1.5, 2.0, 3.0],
        ),
        "all" => {
            for id in
                ["table1", "fig5", "fig8", "fig9", "fig10", "fig6", "fig7", "table2", "table3"]
            {
                run(ctx, id, runs)?;
            }
            Ok(())
        }
        _ => anyhow::bail!("unknown experiment '{id}' (table1|table2|table3|fig5..fig10|all)"),
    }
}
