//! Consolidated experiment suite: trains the model-based agent ONCE per
//! graph and emits every result that depends on it — Fig. 6 (runtime vs
//! baselines), Fig. 8 (WM loss), Fig. 9 (dream reward), Fig. 10
//! (transformation heatmap) and Table 2 (time/memory improvement) — plus
//! the deterministic baselines. On a single-core box this is ~4x cheaper
//! than running the per-figure drivers separately.

use std::collections::HashMap;

use crate::coordinator::Pipeline;
use crate::csv_row;
use crate::env::Env;
use crate::runtime::ParamStore;
use crate::search::{greedy_optimise_cached, taso_optimise_cached, TasoConfig};
use crate::util::csv::CsvWriter;
use crate::util::stats::{ci95, mean, minmax_normalise};
use crate::util::Rng;
use crate::xfer::library::standard_library;

use super::{eval_agent, train_model_based, ExperimentCtx};

pub fn suite(ctx: &ExperimentCtx, runs: usize) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let rules = standard_library();
    // Deterministic baselines honour `--rules`; the RL environments below
    // keep the plain handwritten library (fixed agent action space).
    let search_vocab = ctx.search_rules()?;
    let cost = ctx.cost_model();

    let mut w6 = CsvWriter::create(
        ctx.out("fig6.csv"),
        &["graph", "method", "improvement_pct_mean", "ci95"],
    )?;
    let mut w8 = CsvWriter::create(
        ctx.out("fig8.csv"),
        &["graph", "step", "total", "nll", "reward_mse", "mask_bce", "done_bce"],
    )?;
    let mut w9 =
        CsvWriter::create(ctx.out("fig9.csv"), &["graph", "epoch", "reward", "reward_norm"])?;
    let mut w10 = CsvWriter::create(ctx.out("fig10.csv"), &["graph", "rule", "count"])?;
    let mut w2 = CsvWriter::create(
        ctx.out("table2.csv"),
        &["graph", "tf_ms", "tf_gib", "rlflow_time_impr_pct", "rlflow_mem_impr_pct"],
    )?;
    // `search_cached` flags rows whose taso/greedy timings came from the
    // persistent cache (a repeated suite run, or another driver sharing the
    // ctx): those columns then measure a result-memo lookup, not a search —
    // the CSV must say so, not just stdout.
    let mut w7 = CsvWriter::create(
        ctx.out("fig7.csv"),
        &["graph", "rlflow_s", "taso_s", "greedy_s", "search_cached"],
    )?;

    println!("\n==== consolidated suite: fig6/7/8/9/10 + table2 ====");
    // `--graph <name>` (or -s graph=) restricts the suite to one graph so
    // long runs can be chunked into separate processes; "all"/"bert"
    // default config runs everything when unfiltered via graph=all.
    let filter = ctx.cfg.graph.to_lowercase();
    for (info, g) in crate::zoo::all() {
        if filter != "all" && !info.name.to_lowercase().contains(&filter) {
            continue;
        }
        println!("\n-- {} --", info.name);
        // Deterministic baselines (also Fig. 7 timings), memoised across
        // the whole context: a graph already optimised under the same
        // search config (by an earlier experiment or a repeated suite run)
        // is a pure cache lookup.
        let t0 = std::time::Instant::now();
        let (tf_graph, tf_log) =
            greedy_optimise_cached(&g, &search_vocab, &cost, 60, 0, &ctx.search_cache);
        let greedy_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let (_, taso_log) = taso_optimise_cached(
            &g,
            &search_vocab,
            &cost,
            &TasoConfig::default(),
            &ctx.search_cache,
        );
        let taso_s = t0.elapsed().as_secs_f64();
        println!(
            "   search: {} workers, taso explored {} ({} memo hits{}), greedy {} steps{}",
            taso_log.threads,
            taso_log.graphs_explored,
            taso_log.memo_hits,
            if taso_log.from_cache { ", cached result" } else { "" },
            tf_log.steps.len(),
            if tf_log.from_cache { " (cached result)" } else { "" }
        );

        // One model-based training run.
        let agent = train_model_based(&pipe, &ctx.cfg, &g, ctx.cfg.seed)?;
        for (stage, secs) in &agent.stage_seconds {
            println!("   {:<12} {:>6.1}s", stage, secs);
        }

        // Fig. 8 rows.
        for (i, l) in agent.wm_curve.iter().enumerate() {
            csv_row!(w8; info.name, i, format!("{:.5}", l.total), format!("{:.5}", l.nll), format!("{:.5}", l.reward_mse), format!("{:.5}", l.mask_bce), format!("{:.5}", l.done_bce))?;
        }
        // Fig. 9 rows.
        let curve: Vec<f64> = agent.dream_curve.iter().map(|&r| r as f64).collect();
        let norm = minmax_normalise(&curve);
        for (i, (&r, &nrm)) in curve.iter().zip(&norm).enumerate() {
            csv_row!(w9; info.name, i, format!("{r:.4}"), format!("{nrm:.4}"))?;
        }

        // Evaluation (Fig. 6 RLFlow bar + Fig. 10 history + Fig. 7 timing).
        let t0 = std::time::Instant::now();
        let (rl_scores, history, _) = eval_agent(&pipe, &ctx.cfg, &agent, &g, runs, ctx.cfg.seed)?;
        let rlflow_s = t0.elapsed().as_secs_f64() / runs as f64;

        // Model-free baseline (reduced iterations from the config).
        let mut free_scores = Vec::new();
        {
            let gnn = &agent.gnn; // share the trained encoder
            let mut ctrl = ParamStore::init(ctx.backend, "ctrl", ctx.cfg.seed as i32 + 77)?;
            let mut rng = Rng::new(ctx.cfg.seed + 500);
            let mut env = Env::new(g.clone(), &rules, &cost, ctx.cfg.env.clone());
            for _ in 0..ctx.cfg.free_iterations {
                pipe.model_free_iteration(
                    gnn,
                    &mut ctrl,
                    &mut env,
                    ctx.cfg.free_episodes_per_iter,
                    &ctx.cfg.ppo,
                    &mut rng,
                )?;
            }
            // All `runs` eval episodes advance as one EnvPool batch.
            let results = super::eval_pool_scores(
                &pipe,
                &ctx.cfg.env,
                ctx.cfg.device,
                &g,
                gnn,
                &ctrl,
                None,
                runs,
                ctx.cfg.eval_greedy,
                ctx.cfg.seed + 600,
            )?;
            free_scores.extend(results.iter().map(|r| r.best_improvement_pct));
        }

        // Fig. 6 rows + console table.
        let rows = [
            ("tensorflow", vec![tf_log.improvement_pct()]),
            ("taso", vec![taso_log.improvement_pct()]),
            ("model_free", free_scores),
            ("rlflow", rl_scores.clone()),
        ];
        print!("   fig6:");
        for (method, scores) in &rows {
            let m = mean(scores);
            print!(" {}={:.1}%", method, m);
            csv_row!(w6; info.name, method, format!("{m:.3}"), format!("{:.3}", ci95(scores)))?;
        }
        println!();

        // Fig. 7 row.
        let search_cached = taso_log.from_cache || tf_log.from_cache;
        csv_row!(w7; info.name, format!("{rlflow_s:.4}"), format!("{taso_s:.4}"), format!("{greedy_s:.4}"), search_cached)?;
        println!(
            "   fig7: rlflow {:.2}s | taso {:.2}s | greedy {:.2}s{}",
            rlflow_s,
            taso_s,
            greedy_s,
            if search_cached { " (search columns are cache lookups)" } else { "" }
        );

        // Fig. 10 rows.
        let mut counts: HashMap<usize, usize> = HashMap::new();
        for (xfer, _) in history {
            *counts.entry(xfer).or_default() += 1;
        }
        let mut named: Vec<(&'static str, usize)> = counts
            .into_iter()
            .filter_map(|(x, c)| rules.get(x).map(|r| (r.name(), c)))
            .collect();
        named.sort_by(|a, b| b.1.cmp(&a.1));
        for (name, c) in &named {
            csv_row!(w10; info.name, name, c)?;
        }
        println!("   fig10: {:?}", &named[..named.len().min(6)]);

        // Table 2 row: improvements vs the TF-optimised baseline.
        let tf_ms = cost.graph_runtime_ms(&tf_graph);
        let tf_gib = cost.graph_memory_gib(&tf_graph);
        let raw_ms = cost.graph_runtime_ms(&g);
        let best = rl_scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let rl_ms = raw_ms * (1.0 - best / 100.0);
        let t_impr = 100.0 * (tf_ms - rl_ms) / tf_ms;
        // Memory via the best evaluated graph.
        let mut rng = Rng::new(ctx.cfg.seed);
        let mut env = Env::new(g.clone(), &rules, &cost, ctx.cfg.env.clone());
        let res =
            pipe.eval_real(&agent.gnn, &agent.ctrl, Some(&agent.wm), &mut env, true, &mut rng)?;
        let rl_gib = res
            .best_graph
            .as_ref()
            .map(|bg| cost.graph_memory_gib(bg))
            .unwrap_or(tf_gib);
        let m_impr = 100.0 * (tf_gib - rl_gib) / tf_gib;
        println!(
            "   table2: tf {tf_ms:.2}ms/{tf_gib:.3}GiB, rlflow impr {t_impr:.1}% time / {m_impr:.1}% mem"
        );
        csv_row!(w2; info.name, format!("{tf_ms:.4}"), format!("{tf_gib:.5}"), format!("{t_impr:.2}"), format!("{m_impr:.2}"))?;

        for w in [&mut w6, &mut w7, &mut w8, &mut w9, &mut w10, &mut w2] {
            w.flush()?;
        }
    }
    println!("\n{}", ctx.cache_summary());
    Ok(())
}

/// Temperature sweep sharing one collected dataset + one trained world
/// model across all temperatures (only the controller and its evaluation
/// depend on tau — retraining the WM per temperature would change nothing
/// but cost, cf. §4.8).
pub fn table3_shared(ctx: &ExperimentCtx, runs: usize, temps: &[f32]) -> anyhow::Result<()> {
    let pipe = Pipeline::new(ctx.backend)?;
    let graph = crate::zoo::bert_base();
    let mut rng = Rng::new(ctx.cfg.seed);

    // Shared stages 1-4.
    let mut episodes = crate::coordinator::collect_random_parallel(
        &graph,
        &ctx.cfg.env,
        ctx.cfg.device,
        (pipe.encoder.max_nodes, pipe.encoder.n_feats),
        pipe.dims.x1,
        ctx.cfg.collect_episodes,
        ctx.cfg.collect_noop_prob,
        ctx.cfg.envs,
        ctx.cfg.collect_workers,
        ctx.cfg.seed,
    );
    let mut gnn = ParamStore::init(ctx.backend, "gnn", ctx.cfg.seed as i32)?;
    pipe.train_gnn_ae(&mut gnn, &episodes, ctx.cfg.ae_steps, ctx.cfg.ae_lr, &mut rng)?;
    pipe.encode_episodes(&gnn, &mut episodes)?;
    let mut wm = ParamStore::init(ctx.backend, "wm", ctx.cfg.seed as i32 + 1)?;
    pipe.train_wm(&mut wm, &episodes, &ctx.cfg.wm, &mut rng)?;

    let mut w = CsvWriter::create(
        ctx.out("table3.csv"),
        &["temperature", "wm_score_mean", "wm_score_std", "real_score_mean", "real_score_std"],
    )?;
    println!("\nTable 3: temperature sweep (BERT, shared world model)");
    for &tau in temps {
        let mut ctrl = ParamStore::init(ctx.backend, "ctrl", ctx.cfg.seed as i32 + 2)?;
        let dream_curve = pipe.train_controller_dream(
            &mut ctrl,
            &wm,
            &episodes,
            ctx.cfg.dream_epochs,
            ctx.cfg.dream_horizon,
            tau,
            ctx.cfg.wm.reward_scale,
            &ctx.cfg.ppo,
            &mut rng,
        )?;
        let tail = &dream_curve[dream_curve.len().saturating_sub(5)..];
        let wm_scores: Vec<f64> = tail.iter().map(|&r| r as f64).collect();
        let (wm_mean, wm_std) = crate::util::stats::mean_std(&wm_scores);

        // One pooled pass per temperature: `runs` episodes step together.
        let results = super::eval_pool_scores(
            &pipe,
            &ctx.cfg.env,
            ctx.cfg.device,
            &graph,
            &gnn,
            &ctrl,
            Some(&wm),
            runs,
            ctx.cfg.eval_greedy,
            ctx.cfg.seed ^ (tau.to_bits() as u64),
        )?;
        let real_scores: Vec<f64> = results.iter().map(|r| r.best_improvement_pct).collect();
        let (real_mean, real_std) = crate::util::stats::mean_std(&real_scores);
        println!(
            "  tau {:>5.2}: WM {:>6.2}% ± {:>4.2} | real {:>6.2}% ± {:>4.2}",
            tau, wm_mean, wm_std, real_mean, real_std
        );
        csv_row!(w; tau, format!("{wm_mean:.3}"), format!("{wm_std:.3}"), format!("{real_mean:.3}"), format!("{real_std:.3}"))?;
        w.flush()?;
    }
    Ok(())
}
