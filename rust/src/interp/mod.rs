//! Reference tensor interpreter (DESIGN.md: substitution verification +
//! semantic-equivalence property tests).

pub mod eval;
pub mod localdiff;
pub mod tensor;

pub use eval::{eval_graph, eval_op, eval_outputs};
pub use localdiff::{locally_equivalent, rewrite_flops};
pub use tensor::Tensor;

use std::collections::HashMap;

use crate::graph::{Graph, NodeId, OpKind};
use crate::util::Rng;

/// Are two graphs semantically equivalent on random inputs? (§3.2:
/// `forall I: G(I) = G'(I)`, checked on `trials` random draws.)
///
/// Inputs are matched *by shape signature in first-use order*, mirroring the
/// paper's bounded verification; weights are seeded identically on both
/// sides via the shared `seed`. Returns `Ok(false)` on any mismatch of
/// output arity, shape or value.
pub fn semantically_equal(
    a: &Graph,
    b: &Graph,
    trials: usize,
    seed: u64,
    tol: f32,
) -> anyhow::Result<bool> {
    let a_inputs = input_ids(a);
    let b_inputs = input_ids(b);
    if input_signature(a, &a_inputs) != input_signature(b, &b_inputs) {
        return Ok(false);
    }
    let mut rng = Rng::new(seed);
    for trial in 0..trials {
        let mut feeds_a = HashMap::new();
        let mut feeds_b = HashMap::new();
        for (ia, ib) in a_inputs.iter().zip(&b_inputs) {
            let t = Tensor::random(&a.node(*ia).outs[0].shape, &mut rng);
            feeds_a.insert(*ia, t.clone());
            feeds_b.insert(*ib, t);
        }
        let wseed = seed ^ (trial as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let oa = eval_outputs(a, &feeds_a, wseed)?;
        let ob = eval_outputs(b, &feeds_b, wseed)?;
        if oa.len() != ob.len() {
            return Ok(false);
        }
        for (ta, tb) in oa.iter().zip(&ob) {
            if !ta.allclose(tb, tol) {
                return Ok(false);
            }
        }
    }
    Ok(true)
}

fn input_ids(g: &Graph) -> Vec<NodeId> {
    let mut ids: Vec<NodeId> = g
        .live_ids()
        .filter(|id| matches!(g.node(*id).op, OpKind::Input))
        .collect();
    ids.sort();
    ids
}

fn input_signature(g: &Graph, ids: &[NodeId]) -> Vec<Vec<usize>> {
    ids.iter().map(|id| g.node(*id).outs[0].shape.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder, OpKind};

    #[test]
    fn identical_structures_equal() {
        let build = || {
            let mut b = GraphBuilder::new();
            let x = b.input(&[2, 4]);
            let y = b.input(&[2, 4]);
            let s = b.add(x, y).unwrap();
            let _ = b.relu(s).unwrap();
            b.finish()
        };
        assert!(semantically_equal(&build(), &build(), 3, 42, 1e-5).unwrap());
    }

    #[test]
    fn add_commutes() {
        let mut b1 = GraphBuilder::new();
        let x1 = b1.input(&[2, 4]);
        let y1 = b1.input(&[2, 4]);
        b1.add(x1, y1).unwrap();

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[2, 4]);
        let y2 = b2.input(&[2, 4]);
        b2.add(y2, x2).unwrap();

        assert!(semantically_equal(&b1.finish(), &b2.finish(), 3, 1, 1e-5).unwrap());
    }

    #[test]
    fn different_ops_not_equal() {
        let mut b1 = GraphBuilder::new();
        let x1 = b1.input(&[2, 4]);
        b1.relu(x1).unwrap();

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[2, 4]);
        b2.op(OpKind::Tanh, &[x2]).unwrap();

        assert!(!semantically_equal(&b1.finish(), &b2.finish(), 3, 1, 1e-5).unwrap());
    }

    #[test]
    fn signature_mismatch_short_circuits() {
        let mut b1 = GraphBuilder::new();
        let x1 = b1.input(&[2, 4]);
        b1.relu(x1).unwrap();

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[4, 2]);
        b2.relu(x2).unwrap();

        assert!(!semantically_equal(&b1.finish(), &b2.finish(), 1, 1, 1e-5).unwrap());
    }

    #[test]
    fn linear_vs_manual_matmul_add() {
        // linear(x) == matmul(x, w) + b with identical weight seeding — the
        // weights are drawn in traversal order, which matches when the graph
        // declares w before b in both variants.
        let mut b1 = GraphBuilder::new();
        let x1 = b1.input(&[2, 4]);
        b1.linear(x1, 3, Activation::None).unwrap();
        let g1 = b1.finish();

        let mut b2 = GraphBuilder::new();
        let x2 = b2.input(&[2, 4]);
        let w = b2.weight(&[4, 3]);
        let bias = b2.weight(&[3]);
        let mm = b2
            .op(OpKind::MatMul { trans_a: false, trans_b: false, act: Activation::None }, &[x2, w])
            .unwrap();
        b2.op(OpKind::Add, &[mm, bias]).unwrap();
        let g2 = b2.finish();

        assert!(semantically_equal(&g1, &g2, 3, 11, 1e-4).unwrap());
    }
}
