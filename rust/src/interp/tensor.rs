//! Dense f32 tensor with row-major layout — the value type of the reference
//! interpreter. Deliberately simple: correctness source of truth, not a
//! performance path (the generator only evaluates 4x4x4x4-bounded graphs,
//! mirroring TASO's verification bound, §3.2).

use crate::graph::TensorDesc;
use crate::util::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} does not hold {} elements",
            shape,
            data.len()
        );
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn random(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: (0..n).map(|_| rng.normal()).collect() }
    }

    pub fn n_elems(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn desc(&self) -> TensorDesc {
        TensorDesc::f32(&self.shape)
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.rank());
        let s = self.strides();
        let off: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let s = self.strides();
        let off: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        self.data[off] = v;
    }

    /// Max |a - b| over all elements; `None` on shape mismatch.
    pub fn max_abs_diff(&self, other: &Tensor) -> Option<f32> {
        if self.shape != other.shape {
            return None;
        }
        Some(
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max),
        )
    }

    /// Approximate equality with mixed absolute/relative tolerance.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(a, b)| {
            let scale = 1.0_f32.max(a.abs()).max(b.abs());
            (a - b).abs() <= tol * scale
        })
    }

    /// Apply numpy broadcasting of `self` to `shape` (shape must be a valid
    /// broadcast target).
    pub fn broadcast_to(&self, shape: &[usize]) -> anyhow::Result<Tensor> {
        anyhow::ensure!(
            TensorDesc::broadcast(&self.shape, shape) == Some(shape.to_vec()),
            "cannot broadcast {:?} to {:?}",
            self.shape,
            shape
        );
        let mut out = Tensor::zeros(shape);
        let rank = shape.len();
        let pad = rank - self.rank();
        let src_strides = self.strides();
        let mut idx = vec![0usize; rank];
        for off in 0..out.n_elems() {
            // Decode off -> idx.
            let mut rem = off;
            for d in (0..rank).rev() {
                idx[d] = rem % shape[d];
                rem /= shape[d];
            }
            let mut src_off = 0;
            for d in 0..self.rank() {
                let full_idx = idx[pad + d];
                let i = if self.shape[d] == 1 { 0 } else { full_idx };
                src_off += i * src_strides[d];
            }
            out.data[off] = self.data[src_off];
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn index_round_trip() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set(&[1, 2], 7.0);
        assert_eq!(t.at(&[1, 2]), 7.0);
        assert_eq!(t.data[5], 7.0);
    }

    #[test]
    fn broadcast_scalar_row() {
        let t = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = t.broadcast_to(&[2, 3]).unwrap();
        assert_eq!(b.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn broadcast_column() {
        let t = Tensor::from_vec(&[2, 1], vec![5.0, 6.0]).unwrap();
        let b = t.broadcast_to(&[2, 3]).unwrap();
        assert_eq!(b.data, vec![5.0, 5.0, 5.0, 6.0, 6.0, 6.0]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(&[2], vec![1.0, 100.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![1.0 + 1e-6, 100.0 + 1e-4]).unwrap();
        assert!(a.allclose(&b, 1e-5));
        let c = Tensor::from_vec(&[2], vec![1.1, 100.0]).unwrap();
        assert!(!a.allclose(&c, 1e-5));
    }
}
