//! Local differential equivalence checking for graph rewrites.
//!
//! Verifying a substitution on a full zoo graph means evaluating hundreds
//! of full-resolution convolutions per random draw — far beyond what a
//! test tier can afford. But a rewrite only touches a small region: the
//! nodes it removed, the nodes it added, and the survivors it rewired.
//! This module re-verifies exactly that region.
//!
//! Method: diff the pre/post arenas (slot numbering is stable across a
//! rewrite), extract the removed cone (evaluated against the *before*
//! graph) and the added cone (against the *after* graph), and feed both
//! from a shared pool of random tensors keyed by `(slot, port)` — so a
//! boundary port read by both sides sees the same value. Two observations
//! then pin semantic preservation:
//!
//!  1. every rewired survivor's changed input must carry the same value
//!     before and after, and
//!  2. the multiset of values at changed graph outputs must be preserved.
//!
//! Rules that redirect consumers onto *pre-existing* nodes (identity
//! elimination, common-subexpression merges) compare a removed cone
//! against a surviving producer; those producers are pulled into both
//! sides' evaluation sets symmetrically, so equality is judged on computed
//! values rather than unlucky fresh feeds.
//!
//! Soundness of the locality argument: survivors outside the evaluated
//! region compute the same function of their (unchanged) inputs on both
//! sides, so the whole-graph functions agree iff the boundary values
//! agree — which is what checks 1 and 2 establish on random draws.

use std::collections::HashMap;

use crate::graph::{Graph, NodeId, OpKind, PortRef};
use crate::util::Rng;
use crate::xfer::ApplyReport;

use super::eval::eval_op;
use super::Tensor;

type PortKey = (u32, u16);

fn key(p: PortRef) -> PortKey {
    (p.node.0, p.port)
}

/// Shared random feed pool: one independent tensor per boundary port,
/// seeded per key so demand order never changes the values.
struct Feeds {
    seed: u64,
    cache: HashMap<PortKey, Tensor>,
}

impl Feeds {
    fn new(seed: u64) -> Self {
        Self { seed, cache: HashMap::new() }
    }

    fn get(&mut self, k: PortKey, shape: &[usize]) -> Tensor {
        let seed = self.seed;
        self.cache
            .entry(k)
            .or_insert_with(|| {
                let mix = (k.0 as u64)
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .rotate_left(13)
                    ^ (k.1 as u64).wrapping_mul(0xFF51AFD7ED558CCD);
                Tensor::random(shape, &mut Rng::new(seed ^ mix))
            })
            .clone()
    }
}

/// Demand-driven evaluator over one side's evaluation set: ports produced
/// by in-set nodes are computed (recursively), everything else is fed.
struct SideEval<'g> {
    g: &'g Graph,
    in_set: Vec<bool>,
    memo: HashMap<PortKey, Tensor>,
}

impl<'g> SideEval<'g> {
    fn new(g: &'g Graph, in_set: Vec<bool>) -> Self {
        Self { g, in_set, memo: HashMap::new() }
    }

    fn value(&mut self, p: PortRef, feeds: &mut Feeds) -> anyhow::Result<Tensor> {
        if let Some(t) = self.memo.get(&key(p)) {
            return Ok(t.clone());
        }
        let desc = self.g.out_desc(p)?.clone();
        let idx = p.node.index();
        let node = self.g.node(p.node);
        if !self.in_set[idx] || matches!(node.op, OpKind::Input | OpKind::Weight) {
            return Ok(feeds.get(key(p), &desc.shape));
        }
        let (op, inputs) = (node.op.clone(), node.inputs.clone());
        let ins: Vec<Tensor> = inputs
            .iter()
            .map(|q| self.value(*q, feeds))
            .collect::<anyhow::Result<_>>()?;
        let refs: Vec<&Tensor> = ins.iter().collect();
        let outs = eval_op(&op, &refs)?;
        for (port, t) in outs.into_iter().enumerate() {
            self.memo.insert((p.node.0, port as u16), t);
        }
        self.memo
            .get(&key(p))
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("port {} missing after eval of {:?}", p.port, p.node))
    }
}

/// Rewired-survivor input pairs `(before_port, after_port)` — check 1's
/// comparison list.
fn rewired_pairs(
    before: &Graph,
    after: &Graph,
    report: &ApplyReport,
) -> anyhow::Result<Vec<(PortRef, PortRef)>> {
    let mut pairs = Vec::new();
    for idx in 0..report.prev_slots.min(after.n_slots()) {
        let (b, a) = (&before.nodes[idx], &after.nodes[idx]);
        if b.dead || a.dead || b.inputs == a.inputs {
            continue;
        }
        anyhow::ensure!(
            b.inputs.len() == a.inputs.len(),
            "survivor {:?} changed arity across the rewrite",
            NodeId(idx as u32)
        );
        for (pb, pa) in b.inputs.iter().zip(&a.inputs) {
            if pb != pa {
                pairs.push((*pb, *pa));
            }
        }
    }
    Ok(pairs)
}

/// Output ports present on one side only — check 2's comparison lists.
fn output_diff(before: &Graph, after: &Graph) -> (Vec<PortRef>, Vec<PortRef>) {
    let outs = |g: &Graph| -> Vec<PortRef> {
        let mut ids = g.output_ids();
        ids.sort();
        ids.into_iter()
            .flat_map(|id| {
                (0..g.node(id).outs.len() as u16).map(move |p| PortRef { node: id, port: p })
            })
            .collect()
    };
    let (ob, oa) = (outs(before), outs(after));
    let only_b: Vec<PortRef> = ob.iter().copied().filter(|p| !oa.contains(p)).collect();
    let only_a: Vec<PortRef> = oa.iter().copied().filter(|p| !ob.contains(p)).collect();
    (only_b, only_a)
}

/// Evaluation-set bitmaps for both sides: the changed slots plus the
/// symmetric expansion of compared survivor producers.
fn eval_sets(
    before: &Graph,
    after: &Graph,
    report: &ApplyReport,
    compared: &[PortRef],
) -> (Vec<bool>, Vec<bool>) {
    let n = after.n_slots().max(before.n_slots());
    let mut set_b = vec![false; n];
    let mut set_a = vec![false; n];
    for &id in &report.removed {
        set_b[id.index()] = true;
    }
    for &id in &report.added {
        set_a[id.index()] = true;
    }
    // A compared port produced by a surviving op must be *computed*, not
    // fed, on whichever side reads it — and symmetrically on the other
    // side, so a node demanded by both resolves to one value per side
    // derived from the same feeds.
    for p in compared {
        let idx = p.node.index();
        if set_b[idx] || set_a[idx] {
            continue;
        }
        let live_b = idx < before.n_slots() && !before.nodes[idx].dead;
        let live_a = idx < after.n_slots() && !after.nodes[idx].dead;
        if live_b && !matches!(before.nodes[idx].op, OpKind::Input | OpKind::Weight) {
            set_b[idx] = true;
        }
        if live_a && !matches!(after.nodes[idx].op, OpKind::Input | OpKind::Weight) {
            set_a[idx] = true;
        }
    }
    (set_b, set_a)
}

/// Cheap cost proxy (multiply-accumulates) for evaluating one node.
fn node_flops(g: &Graph, id: NodeId) -> u64 {
    let n = g.node(id);
    let out_elems: usize = n.outs.iter().map(|d| d.n_elems()).sum();
    let in_desc = |k: usize| g.out_desc(n.inputs[k]).ok();
    (match &n.op {
        OpKind::Conv2d { .. } | OpKind::ConvBias { .. } => in_desc(1)
            .map(|w| out_elems * w.shape.iter().skip(1).product::<usize>())
            .unwrap_or(out_elems),
        OpKind::MatMul { trans_a, .. } => in_desc(0)
            .map(|a| {
                let r = a.shape.len();
                let k = if *trans_a { a.shape[r - 2] } else { a.shape[r - 1] };
                out_elems * k
            })
            .unwrap_or(out_elems),
        OpKind::Linear { .. } => in_desc(1)
            .map(|w| out_elems * w.shape[0])
            .unwrap_or(out_elems),
        _ => out_elems,
    }) as u64
}

/// Estimated cost of one local differential check of this rewrite: the
/// removed cone (against `before`) plus the added cone (against `after`).
/// Used by the soundness suite to budget which sites it can afford.
pub fn rewrite_flops(before: &Graph, after: &Graph, report: &ApplyReport) -> u64 {
    let rm: u64 = report.removed.iter().map(|&id| node_flops(before, id)).sum();
    let ad: u64 = report.added.iter().map(|&id| node_flops(after, id)).sum();
    rm + ad
}

/// Differentially check that the rewrite described by `report` preserved
/// semantics, evaluating only the changed region (plus compared survivor
/// producers) on `trials` shared random boundary draws.
///
/// Returns `Ok(false)` when some compared value diverges beyond `tol`
/// (relative, per [`Tensor::allclose`]) or the changed-output multisets
/// cannot be matched; errors indicate a malformed rewrite (arity change,
/// dangling ports) or an op the interpreter rejects.
pub fn locally_equivalent(
    before: &Graph,
    after: &Graph,
    report: &ApplyReport,
    trials: usize,
    seed: u64,
    tol: f32,
) -> anyhow::Result<bool> {
    let pairs = rewired_pairs(before, after, report)?;
    let (only_b, only_a) = output_diff(before, after);
    if pairs.is_empty() && only_b.is_empty() && only_a.is_empty() {
        // The rewrite changed nothing observable (pure dead-code motion).
        return Ok(true);
    }
    let compared: Vec<PortRef> = pairs
        .iter()
        .flat_map(|&(pb, pa)| [pb, pa])
        .chain(only_b.iter().copied())
        .chain(only_a.iter().copied())
        .collect();
    let (set_b, set_a) = eval_sets(before, after, report, &compared);

    for trial in 0..trials {
        let mut feeds = Feeds::new(seed ^ (trial as u64).wrapping_mul(0x2545F4914F6CDD1D));
        let mut eb = SideEval::new(before, set_b.clone());
        let mut ea = SideEval::new(after, set_a.clone());
        // Check 1: rewired survivor inputs carry unchanged values.
        for &(pb, pa) in &pairs {
            let vb = eb.value(pb, &mut feeds)?;
            let va = ea.value(pa, &mut feeds)?;
            if !vb.allclose(&va, tol) {
                return Ok(false);
            }
        }
        // Check 2: changed graph outputs match as a value multiset.
        if only_b.len() != only_a.len() {
            return Ok(false);
        }
        let vb: Vec<Tensor> = only_b
            .iter()
            .map(|&p| eb.value(p, &mut feeds))
            .collect::<anyhow::Result<_>>()?;
        let va: Vec<Tensor> = only_a
            .iter()
            .map(|&p| ea.value(p, &mut feeds))
            .collect::<anyhow::Result<_>>()?;
        let mut used = vec![false; va.len()];
        for t in &vb {
            let hit = va
                .iter()
                .enumerate()
                .find(|(i, u)| !used[*i] && t.allclose(u, tol))
                .map(|(i, _)| i);
            match hit {
                Some(i) => used[i] = true,
                None => return Ok(false),
            }
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, PadMode};
    use crate::xfer::library::standard_library;
    use crate::xfer::{apply_rule, Rule};

    fn check_rule_on(g: &Graph, rule: &dyn Rule) -> usize {
        let mut sites = 0;
        for loc in rule.find(g) {
            let mut g2 = g.clone();
            let report = apply_rule(&mut g2, rule, &loc).unwrap();
            assert!(
                locally_equivalent(g, &g2, &report, 2, 11, 3e-3).unwrap(),
                "rule {} not locally equivalent",
                rule.name()
            );
            sites += 1;
        }
        sites
    }

    #[test]
    fn fusion_rewrites_check_out_locally() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 8, 8]);
        let c = b.conv_bn_relu(x, 4, 3, 1, PadMode::Same).unwrap();
        let _ = b.op(OpKind::Tanh, &[c]).unwrap();
        let g = b.finish();
        let lib = standard_library();
        let mut total = 0;
        for rule in &lib.rules {
            total += check_rule_on(&g, rule.as_ref());
        }
        assert!(total > 0, "no rule fired on the conv-bn-relu host");
    }

    #[test]
    fn splice_to_survivor_is_handled() {
        // transpose(transpose(x)) elimination rewires consumers onto the
        // surviving producer — the symmetric-expansion path.
        let mut b = GraphBuilder::new();
        let x = b.input(&[4, 6]);
        let r = b.relu(x).unwrap();
        let t1 = b.transpose(r, &[1, 0]).unwrap();
        let t2 = b.transpose(t1, &[1, 0]).unwrap();
        let _ = b.op(OpKind::Tanh, &[t2]).unwrap();
        let g = b.finish();
        let lib = standard_library();
        let rule = lib.get(lib.index_of("elim_transpose2").unwrap()).unwrap();
        assert!(check_rule_on(&g, rule) > 0);
    }

    #[test]
    fn a_broken_rewrite_is_caught() {
        // Hand-build an unsound "rewrite": replace relu with tanh.
        let mut b = GraphBuilder::new();
        let x = b.input(&[4, 4]);
        let r = b.relu(x).unwrap();
        let _ = b.op(OpKind::Sigmoid, &[r]).unwrap();
        let g = b.finish();
        let mut g2 = g.clone();
        let prev_slots = g2.n_slots();
        let live_before: Vec<bool> = g2.nodes.iter().map(|n| !n.dead).collect();
        let t = g2.add(OpKind::Tanh, &[PortRef::of(NodeId(0))]).unwrap();
        crate::xfer::apply::splice(&mut g2, r.node, PortRef::of(t)).unwrap();
        g2.dce();
        let report = ApplyReport::diff(&g2, prev_slots, &live_before);
        assert!(!locally_equivalent(&g, &g2, &report, 2, 5, 1e-3).unwrap());
    }

    #[test]
    fn flop_estimate_scales_with_cone() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 3, 16, 16]);
        let c = b.conv(x, 8, 3, 1, PadMode::Same).unwrap();
        let r = b.relu(c).unwrap();
        let _ = b.op(OpKind::Tanh, &[r]).unwrap();
        let g = b.finish();
        let lib = standard_library();
        let rule = lib.get(lib.index_of("fuse_conv_relu").unwrap()).unwrap();
        let loc = rule.find(&g)[0].clone();
        let mut g2 = g.clone();
        let report = apply_rule(&mut g2, rule, &loc).unwrap();
        let f = rewrite_flops(&g, &g2, &report);
        // conv cone dominates: out 8*16*16 elems * 3*3*3 macs, twice.
        assert!(f > 50_000, "estimate suspiciously small: {f}");
    }
}
