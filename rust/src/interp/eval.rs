//! Reference interpreter: execute a [`Graph`] on concrete tensors.
//!
//! Two jobs (DESIGN.md §System inventory):
//!  1. Fingerprint candidate substitutions in the TASO-style generator —
//!     evaluate both sides on random inputs bounded to 4x4x4x4 (§3.2) and
//!     compare.
//!  2. Back property tests: applying any library rule anywhere must leave
//!     the graph's input/output function unchanged.

use std::collections::HashMap;

use crate::graph::{Graph, NodeId, OpKind, PadMode};
use crate::util::Rng;

use super::tensor::Tensor;

/// Evaluate the whole graph. `feeds` supplies Input *and* Weight values by
/// node id; missing weights are generated deterministically from `seed` so
/// two semantically equal graphs with identically-shaped weights in the same
/// traversal order receive the same values.
pub fn eval_graph(
    g: &Graph,
    feeds: &HashMap<NodeId, Tensor>,
    seed: u64,
) -> anyhow::Result<HashMap<NodeId, Vec<Tensor>>> {
    let order = g.topo_order()?;
    let mut values: HashMap<NodeId, Vec<Tensor>> = HashMap::new();
    let mut wrng = Rng::new(seed);
    for id in order {
        let node = g.node(id);
        let outs = match &node.op {
            OpKind::Input | OpKind::Weight => {
                let t = if let Some(t) = feeds.get(&id) {
                    anyhow::ensure!(
                        t.shape == node.outs[0].shape,
                        "feed for {:?} has shape {:?}, node wants {:?}",
                        id,
                        t.shape,
                        node.outs[0].shape
                    );
                    t.clone()
                } else {
                    anyhow::ensure!(
                        matches!(node.op, OpKind::Weight),
                        "missing feed for input {:?}",
                        id
                    );
                    Tensor::random(&node.outs[0].shape, &mut wrng)
                };
                vec![t]
            }
            op => {
                let inputs: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|p| &values[&p.node][p.port as usize])
                    .collect();
                eval_op(op, &inputs)?
            }
        };
        // Interpreter output shapes must agree with static inference.
        for (o, d) in outs.iter().zip(&node.outs) {
            anyhow::ensure!(
                o.shape == d.shape,
                "{}: interpreter shape {:?} != inferred {:?}",
                node.op.name(),
                o.shape,
                d.shape
            );
        }
        values.insert(id, outs);
    }
    Ok(values)
}

/// Evaluate only the graph outputs, sorted by node id for stable comparison.
pub fn eval_outputs(
    g: &Graph,
    feeds: &HashMap<NodeId, Tensor>,
    seed: u64,
) -> anyhow::Result<Vec<Tensor>> {
    let values = eval_graph(g, feeds, seed)?;
    let mut out_ids = g.output_ids();
    out_ids.sort();
    Ok(out_ids
        .iter()
        .flat_map(|id| values[id].clone())
        .collect())
}

fn gelu(x: f32) -> f32 {
    // tanh approximation, matching jax.nn.gelu's default.
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
}

pub fn eval_op(op: &OpKind, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
    use OpKind::*;
    Ok(match op {
        Input | Weight => anyhow::bail!("sources are fed, not evaluated"),
        Conv2d { stride, pad, act } => {
            let y = conv2d(inputs[0], inputs[1], *stride, *pad)?;
            vec![apply_act(y, *act)]
        }
        ConvBias { stride, pad, act } => {
            let y = conv2d(inputs[0], inputs[1], *stride, *pad)?;
            let c = inputs[2].shape[0];
            let b4 = Tensor::from_vec(&[1, c, 1, 1], inputs[2].data.clone())?;
            let y = broadcast_ewise(&y, &b4, |a, b| a + b)?;
            vec![apply_act(y, *act)]
        }
        MatMul { trans_a, trans_b, act } => {
            let y = matmul(inputs[0], inputs[1], *trans_a, *trans_b)?;
            vec![apply_act(y, *act)]
        }
        Linear { act } => {
            let y = matmul(inputs[0], inputs[1], false, false)?;
            let b = inputs[2].broadcast_to(&y.shape)?;
            let y = zip_ewise(&y, &b, |a, b| a + b)?;
            vec![apply_act(y, *act)]
        }
        Add => vec![broadcast_ewise(inputs[0], inputs[1], |a, b| a + b)?],
        Mul => vec![broadcast_ewise(inputs[0], inputs[1], |a, b| a * b)?],
        AddN { .. } => {
            let mut acc = inputs[0].clone();
            for t in &inputs[1..] {
                acc = zip_ewise(&acc, t, |a, b| a + b)?;
            }
            vec![acc]
        }
        Relu => vec![map_ewise(inputs[0], |x| x.max(0.0))],
        Gelu => vec![map_ewise(inputs[0], gelu)],
        Sigmoid => vec![map_ewise(inputs[0], |x| 1.0 / (1.0 + (-x).exp()))],
        Tanh => vec![map_ewise(inputs[0], f32::tanh)],
        Identity => vec![inputs[0].clone()],
        Scale { factor } => {
            let f = *factor;
            vec![map_ewise(inputs[0], move |x| x * f)]
        }
        BatchNorm => vec![batchnorm(inputs[0], inputs[1], inputs[2])?],
        MaxPool { k, stride, pad } => {
            vec![pool(inputs[0], *k, *stride, *pad, f32::NEG_INFINITY, |a, b| a.max(b), |acc, _| acc)?]
        }
        AvgPool { k, stride, pad } => {
            vec![pool(inputs[0], *k, *stride, *pad, 0.0, |a, b| a + b, |acc, n| acc / n as f32)?]
        }
        Concat { axis } => vec![concat(inputs, *axis)?],
        Split { axis, parts } => split(inputs[0], *axis, *parts)?,
        Reshape { shape } =>

            vec![Tensor::from_vec(shape, inputs[0].data.clone())?],
        Transpose { perm } => vec![transpose(inputs[0], perm)],
        Softmax { axis } => vec![softmax(inputs[0], *axis)],
        LayerNorm => vec![layernorm(inputs[0], inputs[1], inputs[2])?],
        FusedAddLayerNorm => {
            let sum = zip_ewise(inputs[0], inputs[1], |a, b| a + b)?;
            vec![layernorm(&sum, inputs[2], inputs[3])?]
        }
        Enlarge { kh, kw } => vec![enlarge(inputs[0], *kh, *kw)?],
    })
}

fn apply_act(t: Tensor, act: crate::graph::Activation) -> Tensor {
    use crate::graph::Activation::*;
    match act {
        None => t,
        Relu => map_ewise(&t, |x| x.max(0.0)),
        Gelu => map_ewise(&t, gelu),
    }
}

fn map_ewise(t: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor { shape: t.shape.clone(), data: t.data.iter().map(|&x| f(x)).collect() }
}

fn zip_ewise(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> anyhow::Result<Tensor> {
    anyhow::ensure!(a.shape == b.shape, "ewise shape mismatch {:?} vs {:?}", a.shape, b.shape);
    Ok(Tensor {
        shape: a.shape.clone(),
        data: a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect(),
    })
}

fn broadcast_ewise(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> anyhow::Result<Tensor> {
    let shape = crate::graph::TensorDesc::broadcast(&a.shape, &b.shape)
        .ok_or_else(|| anyhow::anyhow!("not broadcastable"))?;
    let ab = a.broadcast_to(&shape)?;
    let bb = b.broadcast_to(&shape)?;
    zip_ewise(&ab, &bb, f)
}

fn matmul(a: &Tensor, b: &Tensor, trans_a: bool, trans_b: bool) -> anyhow::Result<Tensor> {
    // Normalise to 3-D batch x M x K without copying data when possible.
    let a2 = maybe_transpose_last2(a, trans_a);
    let b2 = maybe_transpose_last2(b, trans_b);
    let (ar, br) = (a2.rank(), b2.rank());
    let (m, k) = (a2.shape[ar - 2], a2.shape[ar - 1]);
    let (k2, n) = (b2.shape[br - 2], b2.shape[br - 1]);
    anyhow::ensure!(k == k2, "matmul inner dim mismatch");
    let batch_shape = crate::graph::TensorDesc::broadcast(&a2.shape[..ar - 2], &b2.shape[..br - 2])
        .ok_or_else(|| anyhow::anyhow!("matmul batch mismatch"))?;
    let batch: usize = batch_shape.iter().product();

    let mut full_a = batch_shape.clone();
    full_a.extend_from_slice(&[m, k]);
    let mut full_b = batch_shape.clone();
    full_b.extend_from_slice(&[k, n]);
    let ab = a2.broadcast_to(&full_a)?;
    let bb = b2.broadcast_to(&full_b)?;

    let mut out_shape = batch_shape;
    out_shape.extend_from_slice(&[m, n]);
    let mut out = Tensor::zeros(&out_shape);
    for bi in 0..batch {
        let ao = bi * m * k;
        let bo = bi * k * n;
        let oo = bi * m * n;
        for i in 0..m {
            for kk in 0..k {
                let av = ab.data[ao + i * k + kk];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.data[oo + i * n + j] += av * bb.data[bo + kk * n + j];
                }
            }
        }
    }
    Ok(out)
}

fn maybe_transpose_last2(t: &Tensor, trans: bool) -> Tensor {
    if !trans {
        return t.clone();
    }
    let r = t.rank();
    let mut perm: Vec<usize> = (0..r).collect();
    perm.swap(r - 2, r - 1);
    transpose(t, &perm)
}

fn transpose(t: &Tensor, perm: &[usize]) -> Tensor {
    let shape: Vec<usize> = perm.iter().map(|&p| t.shape[p]).collect();
    let mut out = Tensor::zeros(&shape);
    let in_strides = t.strides();
    let rank = t.rank();
    let mut idx = vec![0usize; rank];
    for off in 0..out.n_elems() {
        let mut rem = off;
        for d in (0..rank).rev() {
            idx[d] = rem % shape[d];
            rem /= shape[d];
        }
        let mut src = 0;
        for d in 0..rank {
            src += idx[d] * in_strides[perm[d]];
        }
        out.data[off] = t.data[src];
    }
    out
}

fn conv2d(x: &Tensor, w: &Tensor, stride: usize, pad: PadMode) -> anyhow::Result<Tensor> {
    anyhow::ensure!(x.rank() == 4 && w.rank() == 4, "conv2d wants NCHW x OIHW");
    let (n, c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (co, ci, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    anyhow::ensure!(c == ci, "conv2d channel mismatch");
    let oh = crate::graph::shapes::conv_out_dim(h, kh, stride, pad)
        .ok_or_else(|| anyhow::anyhow!("kernel too large"))?;
    let ow = crate::graph::shapes::conv_out_dim(wd, kw, stride, pad)
        .ok_or_else(|| anyhow::anyhow!("kernel too large"))?;
    // SAME padding offsets (TensorFlow convention).
    let (pt, pl) = match pad {
        PadMode::Valid => (0isize, 0isize),
        PadMode::Same => {
            let pad_h = ((oh - 1) * stride + kh).saturating_sub(h);
            let pad_w = ((ow - 1) * stride + kw).saturating_sub(wd);
            ((pad_h / 2) as isize, (pad_w / 2) as isize)
        }
    };
    let mut out = Tensor::zeros(&[n, co, oh, ow]);
    for ni in 0..n {
        for coi in 0..co {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0;
                    for cii in 0..ci {
                        for ky in 0..kh {
                            let iy = (oy * stride + ky) as isize - pt;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = (ox * stride + kx) as isize - pl;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                acc += x.at(&[ni, cii, iy as usize, ix as usize])
                                    * w.at(&[coi, cii, ky, kx]);
                            }
                        }
                    }
                    out.set(&[ni, coi, oy, ox], acc);
                }
            }
        }
    }
    Ok(out)
}

fn batchnorm(x: &Tensor, scale: &Tensor, shift: &Tensor) -> anyhow::Result<Tensor> {
    anyhow::ensure!(x.rank() == 4, "batchnorm wants NCHW");
    let c = x.shape[1];
    anyhow::ensure!(scale.shape == vec![c] && shift.shape == vec![c], "bn param shape");
    let mut out = x.clone();
    let hw = x.shape[2] * x.shape[3];
    for ni in 0..x.shape[0] {
        for ci in 0..c {
            let base = (ni * c + ci) * hw;
            for i in 0..hw {
                out.data[base + i] = out.data[base + i] * scale.data[ci] + shift.data[ci];
            }
        }
    }
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn pool(
    x: &Tensor,
    k: usize,
    stride: usize,
    pad: PadMode,
    init: f32,
    combine: impl Fn(f32, f32) -> f32,
    finish: impl Fn(f32, usize) -> f32,
) -> anyhow::Result<Tensor> {
    anyhow::ensure!(x.rank() == 4, "pool wants NCHW");
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = crate::graph::shapes::conv_out_dim(h, k, stride, pad)
        .ok_or_else(|| anyhow::anyhow!("window too large"))?;
    let ow = crate::graph::shapes::conv_out_dim(w, k, stride, pad)
        .ok_or_else(|| anyhow::anyhow!("window too large"))?;
    let (pt, pl) = match pad {
        PadMode::Valid => (0isize, 0isize),
        PadMode::Same => {
            let pad_h = ((oh - 1) * stride + k).saturating_sub(h);
            let pad_w = ((ow - 1) * stride + k).saturating_sub(w);
            ((pad_h / 2) as isize, (pad_w / 2) as isize)
        }
    };
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = init;
                    let mut count = 0usize;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pt;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pl;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            acc = combine(acc, x.at(&[ni, ci, iy as usize, ix as usize]));
                            count += 1;
                        }
                    }
                    out.set(&[ni, ci, oy, ox], finish(acc, count.max(1)));
                }
            }
        }
    }
    Ok(out)
}

fn concat(inputs: &[&Tensor], axis: usize) -> anyhow::Result<Tensor> {
    let first = inputs[0];
    let mut out_shape = first.shape.clone();
    out_shape[axis] = inputs.iter().map(|t| t.shape[axis]).sum();
    let mut out = Tensor::zeros(&out_shape);
    let outer: usize = first.shape[..axis].iter().product();
    let inner: usize = first.shape[axis + 1..].iter().product();
    let out_axis = out_shape[axis];
    let mut axis_off = 0;
    for t in inputs {
        let t_axis = t.shape[axis];
        for o in 0..outer {
            for a in 0..t_axis {
                let src = (o * t_axis + a) * inner;
                let dst = (o * out_axis + axis_off + a) * inner;
                out.data[dst..dst + inner].copy_from_slice(&t.data[src..src + inner]);
            }
        }
        axis_off += t_axis;
    }
    Ok(out)
}

fn split(x: &Tensor, axis: usize, parts: usize) -> anyhow::Result<Vec<Tensor>> {
    anyhow::ensure!(x.shape[axis] % parts == 0, "split indivisible");
    let part_axis = x.shape[axis] / parts;
    let mut shape = x.shape.clone();
    shape[axis] = part_axis;
    let outer: usize = x.shape[..axis].iter().product();
    let inner: usize = x.shape[axis + 1..].iter().product();
    let mut outs = vec![Tensor::zeros(&shape); parts];
    for (p, out) in outs.iter_mut().enumerate() {
        for o in 0..outer {
            for a in 0..part_axis {
                let src = (o * x.shape[axis] + p * part_axis + a) * inner;
                let dst = (o * part_axis + a) * inner;
                out.data[dst..dst + inner].copy_from_slice(&x.data[src..src + inner]);
            }
        }
    }
    Ok(outs)
}

fn softmax(x: &Tensor, axis: usize) -> Tensor {
    let axis_len = x.shape[axis];
    let outer: usize = x.shape[..axis].iter().product();
    let inner: usize = x.shape[axis + 1..].iter().product();
    let mut out = x.clone();
    for o in 0..outer {
        for i in 0..inner {
            let idx = |a: usize| (o * axis_len + a) * inner + i;
            let mut mx = f32::NEG_INFINITY;
            for a in 0..axis_len {
                mx = mx.max(out.data[idx(a)]);
            }
            let mut sum = 0.0;
            for a in 0..axis_len {
                let e = (out.data[idx(a)] - mx).exp();
                out.data[idx(a)] = e;
                sum += e;
            }
            for a in 0..axis_len {
                out.data[idx(a)] /= sum;
            }
        }
    }
    out
}

fn layernorm(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> anyhow::Result<Tensor> {
    let d = *x.shape.last().unwrap();
    anyhow::ensure!(gamma.shape == vec![d] && beta.shape == vec![d], "ln param shape");
    let rows = x.n_elems() / d;
    let mut out = x.clone();
    for r in 0..rows {
        let row = &mut out.data[r * d..(r + 1) * d];
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma.data[i] + beta.data[i];
        }
    }
    Ok(out)
}

fn enlarge(w: &Tensor, kh: usize, kw: usize) -> anyhow::Result<Tensor> {
    anyhow::ensure!(w.rank() == 4, "enlarge wants OIHW");
    let (co, ci, oh, ow) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (dy, dx) = ((kh - oh) / 2, (kw - ow) / 2);
    let mut out = Tensor::zeros(&[co, ci, kh, kw]);
    for a in 0..co {
        for b in 0..ci {
            for y in 0..oh {
                for x in 0..ow {
                    out.set(&[a, b, y + dy, x + dx], w.at(&[a, b, y, x]));
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, GraphBuilder};

    #[test]
    fn matmul_2x2() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_transpose_consistency() {
        let mut rng = Rng::new(0);
        let a = Tensor::random(&[3, 4], &mut rng);
        let b = Tensor::random(&[5, 4], &mut rng);
        let direct = matmul(&a, &b, false, true).unwrap();
        let bt = transpose(&b, &[1, 0]);
        let via = matmul(&a, &bt, false, false).unwrap();
        assert!(direct.allclose(&via, 1e-6));
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with single 1.0 acts as identity on channels=1.
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let y = conv2d(&x, &w, 1, PadMode::Same).unwrap();
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_valid_window_sum() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let y = conv2d(&x, &w, 1, PadMode::Valid).unwrap();
        assert_eq!(y.shape, vec![1, 1, 1, 1]);
        assert_eq!(y.data, vec![10.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let x = Tensor::random(&[3, 5], &mut rng);
        let s = softmax(&x, 1);
        for r in 0..3 {
            let sum: f32 = s.data[r * 5..(r + 1) * 5].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(2);
        let x = Tensor::random(&[4, 8], &mut rng);
        let gamma = Tensor::from_vec(&[8], vec![1.0; 8]).unwrap();
        let beta = Tensor::zeros(&[8]);
        let y = layernorm(&x, &gamma, &beta).unwrap();
        for r in 0..4 {
            let row = &y.data[r * 8..(r + 1) * 8];
            let mean: f32 = row.iter().sum::<f32>() / 8.0;
            assert!(mean.abs() < 1e-4);
        }
    }

    #[test]
    fn split_concat_inverse() {
        let mut rng = Rng::new(3);
        let x = Tensor::random(&[2, 6, 3], &mut rng);
        let parts = split(&x, 1, 3).unwrap();
        let refs: Vec<&Tensor> = parts.iter().collect();
        let back = concat(&refs, 1).unwrap();
        assert_eq!(back.data, x.data);
    }

    #[test]
    fn enlarge_preserves_conv_same_result() {
        // conv(x, w3) == conv(x, enlarge(w3 -> 5)) under SAME padding.
        let mut rng = Rng::new(4);
        let x = Tensor::random(&[1, 2, 6, 6], &mut rng);
        let w = Tensor::random(&[3, 2, 3, 3], &mut rng);
        let y1 = conv2d(&x, &w, 1, PadMode::Same).unwrap();
        let w5 = enlarge(&w, 5, 5).unwrap();
        let y2 = conv2d(&x, &w5, 1, PadMode::Same).unwrap();
        assert!(y1.allclose(&y2, 1e-5), "max diff {:?}", y1.max_abs_diff(&y2));
    }

    #[test]
    fn graph_eval_end_to_end() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[1, 4]);
        let y = b.linear(x, 3, Activation::Relu).unwrap();
        let g = b.finish();
        let mut feeds = HashMap::new();
        feeds.insert(x.node, Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        let outs = eval_outputs(&g, &feeds, 7).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape, vec![1, 3]);
        assert!(outs[0].data.iter().all(|&v| v >= 0.0));
        let _ = y;
    }

    #[test]
    fn deterministic_weight_seeding() {
        let mut b = GraphBuilder::new();
        let x = b.input(&[2, 4]);
        let _ = b.linear(x, 4, Activation::None).unwrap();
        let g = b.finish();
        let mut feeds = HashMap::new();
        let mut rng = Rng::new(9);
        feeds.insert(x.node, Tensor::random(&[2, 4], &mut rng));
        let o1 = eval_outputs(&g, &feeds, 5).unwrap();
        let o2 = eval_outputs(&g, &feeds, 5).unwrap();
        let o3 = eval_outputs(&g, &feeds, 6).unwrap();
        assert_eq!(o1[0].data, o2[0].data);
        assert_ne!(o1[0].data, o3[0].data);
    }
}
