//! Bench: environment step throughput — the denominator of RLFlow's
//! sample-efficiency story (§3.1). Three rows per graph:
//!
//!  * `seed` — the pre-incremental environment (`full_refresh: true`):
//!    every step re-runs all `Rule::find`s and a full cost recompute;
//!  * `incr` — the incremental environment: dirty-region match
//!    maintenance + `delta_cost_fast` rewards;
//!  * `pool B` — `EnvPool` at B = 1/4/8 environments, aggregate steps/sec
//!    across the batch.
//!
//! `parity` checks the incremental walk visited exactly the same history
//! as the seed walk (same seeded policy → bit-identical observations).
//! Results are appended to BENCH_env.json at the repository root.

use std::time::Instant;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig, EnvPool, EnvPoolConfig};
use rlflow::util::Rng;
use rlflow::xfer::library::standard_library;

const WALK_STEPS: usize = 40;
const POOL_SIZES: [usize; 3] = [1, 4, 8];

/// Seeded random valid-action walk; resets when an episode ends or the
/// graph runs out of matches. Deterministic given the env + seed.
fn walk(env: &mut Env, rng: &mut Rng, steps: usize) -> Vec<(usize, usize)> {
    let n_rules = env.rules.len();
    let mut history = Vec::with_capacity(steps);
    for _ in 0..steps {
        let obs = env.observe();
        let valid: Vec<usize> = (0..n_rules).filter(|&i| obs.xfer_mask[i]).collect();
        if valid.is_empty() {
            env.reset();
            continue;
        }
        let x = valid[rng.below(valid.len())];
        let l = rng.below(obs.location_counts[x].max(1));
        let res = env.step((x, l));
        history.push((x, l));
        if res.done {
            env.reset();
        }
    }
    history
}

fn main() {
    let rules = standard_library();
    println!(
        "{:<15} {:>10} {:>10} {:>7} {:>8} {}",
        "Graph", "seed st/s", "incr st/s", "speedup", "parity", "pool st/s (B=1/4/8)"
    );
    let mut json_rows = Vec::new();
    for (info, g) in rlflow::zoo::all() {
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mut env = Env::new(
            g.clone(),
            &rules,
            &cost,
            EnvConfig { full_refresh: true, ..Default::default() },
        );
        let t0 = Instant::now();
        let seed_history = walk(&mut env, &mut Rng::new(0xBEEF), WALK_STEPS);
        let seed_sps = seed_history.len() as f64 / t0.elapsed().as_secs_f64();

        let cost = CostModel::new(DeviceProfile::rtx2070());
        let mut env = Env::new(g.clone(), &rules, &cost, EnvConfig::default());
        let t0 = Instant::now();
        let incr_history = walk(&mut env, &mut Rng::new(0xBEEF), WALK_STEPS);
        let incr_sps = incr_history.len() as f64 / t0.elapsed().as_secs_f64();
        let parity = seed_history == incr_history;
        let stats = env.state().match_stats();

        let mut pool_sps = Vec::new();
        for &b in &POOL_SIZES {
            let base = CostModel::new(DeviceProfile::rtx2070());
            let mut pool = EnvPool::new(
                &g,
                standard_library(),
                &base,
                &EnvPoolConfig { n_envs: b, seed: 0xBEEF, ..Default::default() },
            );
            let t0 = Instant::now();
            let per_env = pool.map_envs(|_, env, rng| walk(env, rng, WALK_STEPS).len());
            let total: usize = per_env.iter().sum();
            pool_sps.push(total as f64 / t0.elapsed().as_secs_f64());
        }

        println!(
            "{:<15} {:>10.1} {:>10.1} {:>6.1}x {:>8} {:>8.1} /{:>8.1} /{:>8.1}   (refinds {} keeps {})",
            info.name,
            seed_sps,
            incr_sps,
            incr_sps / seed_sps.max(1e-9),
            if parity { "ok" } else { "DIVERGED" },
            pool_sps[0],
            pool_sps[1],
            pool_sps[2],
            stats.refinds,
            stats.keeps,
        );
        json_rows.push(format!(
            concat!(
                "    {{\"graph\": \"{}\", \"walk_steps\": {}, \"seed_steps_per_s\": {:.2}, ",
                "\"incremental_steps_per_s\": {:.2}, \"speedup\": {:.3}, \"parity\": {}, ",
                "\"pool_steps_per_s\": {{\"1\": {:.2}, \"4\": {:.2}, \"8\": {:.2}}}, ",
                "\"match_refinds\": {}, \"match_keeps\": {}}}"
            ),
            info.name,
            WALK_STEPS,
            seed_sps,
            incr_sps,
            incr_sps / seed_sps.max(1e-9),
            parity,
            pool_sps[0],
            pool_sps[1],
            pool_sps[2],
            stats.refinds,
            stats.keeps,
        ));
    }

    // `cargo bench` runs from the package root (rust/); the results file
    // lives beside CHANGES.md at the repository root.
    let out = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_env.json"
    } else {
        "BENCH_env.json"
    };
    let json = format!(
        "{{\n  \"bench\": \"fig8_env_throughput\",\n  \"placeholder\": false,\n  \"walk_steps\": {},\n  \"rows\": [\n{}\n  ]\n}}\n",
        WALK_STEPS,
        json_rows.join(",\n")
    );
    match std::fs::write(out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
