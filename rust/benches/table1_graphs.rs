//! Bench: Table 1 pipeline costs — zoo construction, canonical hashing,
//! substitution matching and state encoding per evaluation graph. These are
//! the L3 operations on the environment's hot path; Fig. 7's optimisation
//! times decompose into them.
//!
//! Plain harness (`harness = false`): prints mean wall-clock per op.

use std::time::Instant;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::StateEncoder;
use rlflow::graph::canonical_hash;
use rlflow::xfer::library::standard_library;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {:<28} {:>10.3} ms/iter  ({} iters)", name, per * 1e3, iters);
}

fn main() {
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let encoder = StateEncoder::new(320, 32);

    println!("table1_graphs bench: per-graph pipeline costs");
    for (info, g) in rlflow::zoo::all() {
        println!("{} ({} ops):", info.name, g.n_ops());
        bench("construct", 10, || {
            let _ = rlflow::zoo::by_name(info.name).unwrap();
        });
        bench("canonical_hash", 50, || {
            let _ = canonical_hash(&g);
        });
        bench("match_all_rules", 20, || {
            let _ = rules.count_matches(&g);
        });
        bench("graph_cost", 50, || {
            let cm = CostModel::new(DeviceProfile::rtx2070());
            let _ = cm.graph_cost(&g);
        });
        bench("graph_cost_cached", 200, || {
            let _ = cost.graph_cost(&g);
        });
        bench("graph_cost_fast", 200, || {
            let _ = cost.graph_cost_fast(&g);
        });
        bench("encode_state", 20, || {
            let _ = encoder.encode(&g);
        });
    }
}
