//! Bench: L3 hot-path micro-benchmarks + artifact execution latencies.
//!
//! Covers every operation on the per-step critical path of training and
//! evaluation; §Perf in EXPERIMENTS.md tracks these numbers before/after
//! optimisation. Artifact timings are skipped when artifacts are missing.

use std::time::Instant;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig, StateEncoder};
use rlflow::runtime::{lit_f32, lit_i32, Engine, Manifest, ParamStore};
use rlflow::util::Rng;
use rlflow::xfer::library::standard_library;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {:<28} {:>10.3} ms/iter  ({} iters)", name, per * 1e3, iters);
    per
}

fn main() -> anyhow::Result<()> {
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let bert = rlflow::zoo::bert_base();
    let encoder = StateEncoder::new(320, 32);

    println!("== L3 environment hot path (BERT) ==");
    let fuse = rules.index_of("fuse_add_ln").unwrap();
    bench("env.new (match + cost)", 10, || {
        let _ = Env::new(bert.clone(), &rules, &cost, EnvConfig::default());
    });
    // Steady-state step cost, incremental vs the full-refresh reference
    // (construction excluded; fig8_env_throughput has the full table).
    {
        let mut env = Env::new(bert.clone(), &rules, &cost, EnvConfig::default());
        bench("env.step (incremental)", 10, || {
            if env.observe().location_counts[fuse] == 0 {
                env.reset();
            }
            let _ = env.step((fuse, 0));
        });
    }
    {
        let mut env = Env::new(
            bert.clone(),
            &rules,
            &cost,
            EnvConfig { full_refresh: true, ..Default::default() },
        );
        bench("env.step (full refresh)", 10, || {
            if env.observe().location_counts[fuse] == 0 {
                env.reset();
            }
            let _ = env.step((fuse, 0));
        });
    }
    bench("encoder.encode", 20, || {
        let _ = encoder.encode(&bert);
    });
    bench("rule.find fuse_add_ln", 100, || {
        let _ = rules.get(fuse).unwrap().find(&bert);
    });
    bench("count_matches (all rules)", 10, || {
        let _ = rules.count_matches(&bert);
    });
    bench("graph.clone", 100, || {
        let _ = bert.clone();
    });
    bench("graph_cost (full)", 100, || {
        let _ = cost.graph_cost(&bert);
    });
    bench("graph_cost_fast (hot path)", 200, || {
        let _ = cost.graph_cost_fast(&bert);
    });

    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("\nartifacts not built — skipping artifact latency benches");
        return Ok(());
    }

    println!("\n== artifact execution latencies (PJRT CPU) ==");
    let engine = Engine::load_default()?;
    let m = &engine.manifest;
    let (n, f) = (m.hp_usize("MAX_NODES")?, m.hp_usize("NODE_FEATS")?);
    let zdim = m.hp_usize("LATENT")?;
    let r = m.hp_usize("RNN_HIDDEN")?;
    let gnn = ParamStore::init(&engine, "gnn", 0)?;
    let wm = ParamStore::init(&engine, "wm", 1)?;
    let ctrl = ParamStore::init(&engine, "ctrl", 2)?;
    engine.warmup(&["gnn_encode_1", "wm_step_1", "wm_step_b", "ctrl_policy_1", "ctrl_policy_b"])?;

    let e = encoder.encode(&bert);
    let feats = lit_f32(&e.feats, &[1, n, f])?;
    let adj = lit_f32(&e.adj, &[1, n, n])?;
    let mask = lit_f32(&e.mask, &[1, n])?;
    bench("gnn_encode_1 (BERT state)", 20, || {
        let _ = engine
            .exec("gnn_encode_1", &[gnn.theta_lit().unwrap(), feats.clone(), adj.clone(), mask.clone()])
            .unwrap();
    });

    let z1 = lit_f32(&vec![0.1; zdim], &[1, zdim])?;
    let a1 = lit_i32(&[0, 0], &[1, 2])?;
    let h1 = lit_f32(&vec![0.0; r], &[1, r])?;
    let c1 = lit_f32(&vec![0.0; r], &[1, r])?;
    let wm_step_ms = bench("wm_step_1 (dream step b=1)", 50, || {
        let _ = engine
            .exec("wm_step_1", &[wm.theta_lit().unwrap(), z1.clone(), a1.clone(), h1.clone(), c1.clone()])
            .unwrap();
    });

    let b = m.hp_usize("B_DREAM")?;
    let zb = lit_f32(&vec![0.1; b * zdim], &[b, zdim])?;
    let ab = lit_i32(&vec![0; b * 2], &[b, 2])?;
    let hb = lit_f32(&vec![0.0; b * r], &[b, r])?;
    let cb = lit_f32(&vec![0.0; b * r], &[b, r])?;
    bench("wm_step_b (dream batch)", 50, || {
        let _ = engine
            .exec("wm_step_b", &[wm.theta_lit().unwrap(), zb.clone(), ab.clone(), hb.clone(), cb.clone()])
            .unwrap();
    });

    bench("ctrl_policy_1 (theta upload)", 20, || {
        let _ = engine
            .exec("ctrl_policy_1", &[ctrl.theta_lit().unwrap(), z1.clone(), h1.clone()])
            .unwrap();
    });
    let theta_ctrl = engine.device_theta(&ctrl).unwrap();
    let ctrl_cached_ms = bench("ctrl_policy_1 (theta cached)", 50, || {
        let _ = engine
            .exec_with_theta("ctrl_policy_1", &theta_ctrl, &[z1.clone(), h1.clone()])
            .unwrap();
    });

    println!("\n== dream vs real acting step (the §4.4 85x comparison) ==");
    // Real acting step = encode + policy + env.step + wm hidden advance;
    // dream acting step = (policy_b + wm_step_b) / B_DREAM.
    let mut env = Env::new(bert.clone(), &rules, &cost, EnvConfig::default());
    let mut rng = Rng::new(0);
    let theta_gnn = engine.device_theta(&gnn).unwrap();
    let theta_wm = engine.device_theta(&wm).unwrap();
    let t0 = Instant::now();
    let mut steps = 0usize;
    while steps < 10 {
        let e = encoder.encode(env.graph());
        let _z = engine
            .exec_with_theta(
                "gnn_encode_1",
                &theta_gnn,
                &[
                    lit_f32(&e.feats, &[1, n, f]).unwrap(),
                    lit_f32(&e.adj, &[1, n, n]).unwrap(),
                    lit_f32(&e.mask, &[1, n]).unwrap(),
                ],
            )
            .unwrap();
        let _pol = engine
            .exec_with_theta("ctrl_policy_1", &theta_ctrl, &[z1.clone(), h1.clone()])
            .unwrap();
        let obs = env.observe();
        let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
        if valid.is_empty() {
            env.reset();
            continue;
        }
        let x = valid[rng.below(valid.len())];
        let l = rng.below(obs.location_counts[x].max(1));
        let res = env.step((x, l));
        let _wm = engine
            .exec_with_theta("wm_step_1", &theta_wm, &[z1.clone(), a1.clone(), h1.clone(), c1.clone()])
            .unwrap();
        steps += 1;
        if res.done {
            env.reset();
        }
    }
    let real_ms = t0.elapsed().as_secs_f64() / steps as f64 * 1e3;
    let t0 = Instant::now();
    for _ in 0..20 {
        let _pol = engine
            .exec_with_theta("ctrl_policy_b", &theta_ctrl, &[zb.clone(), hb.clone()])
            .unwrap();
        let _wm = engine
            .exec_with_theta("wm_step_b", &theta_wm, &[zb.clone(), ab.clone(), hb.clone(), cb.clone()])
            .unwrap();
    }
    let dream_ms = t0.elapsed().as_secs_f64() / (20 * b) as f64 * 1e3;
    println!("  real acting step (BERT)      {:>10.3} ms", real_ms);
    println!("  dream acting step (/B={b})   {:>10.3} ms", dream_ms);
    println!("  ratio                        {:>10.1}x", real_ms / dream_ms);
    let _ = (wm_step_ms, ctrl_cached_ms);
    Ok(())
}
