//! Bench: L3 hot-path micro-benchmarks + model-program execution latencies.
//!
//! Covers every operation on the per-step critical path of training and
//! evaluation; §Perf in EXPERIMENTS.md tracks these numbers before/after
//! optimisation. Program latencies run on whatever backend `auto` resolves
//! to — the PJRT artifacts when built, the pure-Rust host backend
//! otherwise — so this section no longer skips offline.

use std::time::Instant;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::env::{Env, EnvConfig, StateEncoder};
use rlflow::runtime::{backend_by_name, Backend, ParamStore, TensorView};
use rlflow::util::Rng;
use rlflow::xfer::library::standard_library;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {:<28} {:>10.3} ms/iter  ({} iters)", name, per * 1e3, iters);
    per
}

fn main() -> anyhow::Result<()> {
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    let bert = rlflow::zoo::bert_base();
    let encoder = StateEncoder::new(320, 32);

    println!("== L3 environment hot path (BERT) ==");
    let fuse = rules.index_of("fuse_add_ln").unwrap();
    bench("env.new (match + cost)", 10, || {
        let _ = Env::new(bert.clone(), &rules, &cost, EnvConfig::default());
    });
    // Steady-state step cost, incremental vs the full-refresh reference
    // (construction excluded; fig8_env_throughput has the full table).
    {
        let mut env = Env::new(bert.clone(), &rules, &cost, EnvConfig::default());
        bench("env.step (incremental)", 10, || {
            if env.observe().location_counts[fuse] == 0 {
                env.reset();
            }
            let _ = env.step((fuse, 0));
        });
    }
    {
        let mut env = Env::new(
            bert.clone(),
            &rules,
            &cost,
            EnvConfig { full_refresh: true, ..Default::default() },
        );
        bench("env.step (full refresh)", 10, || {
            if env.observe().location_counts[fuse] == 0 {
                env.reset();
            }
            let _ = env.step((fuse, 0));
        });
    }
    bench("encoder.encode", 20, || {
        let _ = encoder.encode(&bert);
    });
    bench("rule.find fuse_add_ln", 100, || {
        let _ = rules.get(fuse).unwrap().find(&bert);
    });
    bench("count_matches (all rules)", 10, || {
        let _ = rules.count_matches(&bert);
    });
    bench("graph.clone", 100, || {
        let _ = bert.clone();
    });
    bench("graph_cost (full)", 100, || {
        let _ = cost.graph_cost(&bert);
    });
    bench("graph_cost_fast (hot path)", 200, || {
        let _ = cost.graph_cost_fast(&bert);
    });

    let backend = backend_by_name("auto")?;
    println!("\n== model-program latencies (backend: {}) ==", backend.name());
    let m = backend.manifest();
    let (n, f) = (m.hp_usize("MAX_NODES")?, m.hp_usize("NODE_FEATS")?);
    let zdim = m.hp_usize("LATENT")?;
    let r = m.hp_usize("RNN_HIDDEN")?;
    let b = m.hp_usize("B_DREAM")?;
    let gnn = ParamStore::init(backend.as_ref(), "gnn", 0)?;
    let wm = ParamStore::init(backend.as_ref(), "wm", 1)?;
    let ctrl = ParamStore::init(backend.as_ref(), "ctrl", 2)?;

    // Encoder sized to the backend's manifest (host dims may differ).
    let benc = StateEncoder::new(n, f);
    let e = benc.encode(&bert);
    bench("gnn_encode_1 (BERT state)", 20, || {
        let _ = backend
            .exec_with_params(
                "gnn_encode_1",
                &gnn,
                &[
                    TensorView::f32(&e.feats, &[1, n, f]),
                    TensorView::f32(&e.adj, &[1, n, n]),
                    TensorView::f32(&e.mask, &[1, n]),
                ],
            )
            .unwrap();
    });

    let z1 = vec![0.1f32; zdim];
    let a1 = [0i32, 0];
    let h1 = vec![0.0f32; r];
    let c1 = vec![0.0f32; r];
    let wm_step_ms = bench("wm_step_1 (dream step b=1)", 50, || {
        let _ = backend
            .exec_with_params(
                "wm_step_1",
                &wm,
                &[
                    TensorView::f32(&z1, &[1, zdim]),
                    TensorView::i32(&a1, &[1, 2]),
                    TensorView::f32(&h1, &[1, r]),
                    TensorView::f32(&c1, &[1, r]),
                ],
            )
            .unwrap();
    });

    let zb = vec![0.1f32; b * zdim];
    let ab = vec![0i32; b * 2];
    let hb = vec![0.0f32; b * r];
    let cb = vec![0.0f32; b * r];
    bench("wm_step_b (dream batch)", 50, || {
        let _ = backend
            .exec_with_params(
                "wm_step_b",
                &wm,
                &[
                    TensorView::f32(&zb, &[b, zdim]),
                    TensorView::i32(&ab, &[b, 2]),
                    TensorView::f32(&hb, &[b, r]),
                    TensorView::f32(&cb, &[b, r]),
                ],
            )
            .unwrap();
    });

    let ctrl_ms = bench("ctrl_policy_1 (cached theta)", 50, || {
        let _ = backend
            .exec_with_params(
                "ctrl_policy_1",
                &ctrl,
                &[TensorView::f32(&z1, &[1, zdim]), TensorView::f32(&h1, &[1, r])],
            )
            .unwrap();
    });

    println!("\n== dream vs real acting step (the §4.4 85x comparison) ==");
    // Real acting step = encode + policy + env.step + wm hidden advance;
    // dream acting step = (policy_b + wm_step_b) / B_DREAM.
    let mut env = Env::new(bert.clone(), &rules, &cost, EnvConfig::default());
    let mut rng = Rng::new(0);
    let t0 = Instant::now();
    let mut steps = 0usize;
    while steps < 10 {
        let es = benc.encode(env.graph());
        let _z = backend
            .exec_with_params(
                "gnn_encode_1",
                &gnn,
                &[
                    TensorView::f32(&es.feats, &[1, n, f]),
                    TensorView::f32(&es.adj, &[1, n, n]),
                    TensorView::f32(&es.mask, &[1, n]),
                ],
            )
            .unwrap();
        let _pol = backend
            .exec_with_params(
                "ctrl_policy_1",
                &ctrl,
                &[TensorView::f32(&z1, &[1, zdim]), TensorView::f32(&h1, &[1, r])],
            )
            .unwrap();
        let obs = env.observe();
        let valid: Vec<usize> = (0..rules.len()).filter(|&i| obs.xfer_mask[i]).collect();
        if valid.is_empty() {
            env.reset();
            continue;
        }
        let x = valid[rng.below(valid.len())];
        let l = rng.below(obs.location_counts[x].max(1));
        let res = env.step((x, l));
        let _wm = backend
            .exec_with_params(
                "wm_step_1",
                &wm,
                &[
                    TensorView::f32(&z1, &[1, zdim]),
                    TensorView::i32(&a1, &[1, 2]),
                    TensorView::f32(&h1, &[1, r]),
                    TensorView::f32(&c1, &[1, r]),
                ],
            )
            .unwrap();
        steps += 1;
        if res.done {
            env.reset();
        }
    }
    let real_ms = t0.elapsed().as_secs_f64() / steps as f64 * 1e3;
    let t0 = Instant::now();
    for _ in 0..20 {
        let _pol = backend
            .exec_with_params(
                "ctrl_policy_b",
                &ctrl,
                &[TensorView::f32(&zb, &[b, zdim]), TensorView::f32(&hb, &[b, r])],
            )
            .unwrap();
        let _wm = backend
            .exec_with_params(
                "wm_step_b",
                &wm,
                &[
                    TensorView::f32(&zb, &[b, zdim]),
                    TensorView::i32(&ab, &[b, 2]),
                    TensorView::f32(&hb, &[b, r]),
                    TensorView::f32(&cb, &[b, r]),
                ],
            )
            .unwrap();
    }
    let dream_ms = t0.elapsed().as_secs_f64() / (20 * b) as f64 * 1e3;
    println!("  real acting step (BERT)      {:>10.3} ms", real_ms);
    println!("  dream acting step (/B={b})   {:>10.3} ms", dream_ms);
    println!("  ratio                        {:>10.1}x", real_ms / dream_ms);
    let _ = (wm_step_ms, ctrl_ms);
    Ok(())
}
