//! Bench: Fig. 7 — end-to-end optimisation time per graph for the two
//! deterministic search baselines (greedy / TASO). The RLFlow rollout side
//! of Fig. 7 needs trained artifacts and lives in
//! `rlflow experiment fig7`; this bench isolates the search costs, which
//! dominate TASO's bar in the paper.
//!
//! Three timing tiers per graph:
//!
//!  * `seed` — the pre-engine sequential path (single thread, no
//!    memoisation, full cost recompute per candidate — the `*_reference`
//!    oracles);
//!  * `engine` — the parallel location-sharded engine (scoped worker
//!    threads, transposition table, incremental delta costing), cold;
//!  * `warm` — the same search repeated against a persistent
//!    `SearchCache`: a pure result-memo lookup, the cross-run amortisation
//!    `experiments::suite` relies on.
//!
//! `cost ok` checks engine and warm runs found the same final cost as the
//! seed path (to 1e-6 relative; the warm lookup is bit-identical to the
//! cold engine run by construction). Results are appended to
//! BENCH_search.json at the repository root.

use std::time::Instant;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::search::{
    greedy_optimise, greedy_optimise_reference, taso_optimise, taso_optimise_cached,
    taso_optimise_reference, SearchCache, TasoConfig,
};
use rlflow::xfer::library::standard_library;

fn main() {
    let rules = standard_library();
    let mut workers = 0;
    println!(
        "{:<15} {:>10} {:>10} {:>8} {:>10} {:>10} {:>8} {:>10} {:>9} {:>8}",
        "Graph",
        "greedy(s)",
        "g-eng(s)",
        "g-spd",
        "taso(s)",
        "t-eng(s)",
        "t-spd",
        "t-warm(s)",
        "memohits",
        "cost ok"
    );
    let mut json_rows = Vec::new();
    for (info, g) in rlflow::zoo::all() {
        // Fresh cost model per timed run: the per-op cost cache persists
        // inside a CostModel, so sharing one would let the seed run warm
        // the cache for the engine run (or vice versa) and bias the
        // speedup columns.
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, gref) = greedy_optimise_reference(&g, &rules, &cost, 50);
        let greedy_seed_s = t0.elapsed().as_secs_f64();

        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, geng) = greedy_optimise(&g, &rules, &cost, 50);
        let greedy_eng_s = t0.elapsed().as_secs_f64();

        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, tref) = taso_optimise_reference(&g, &rules, &cost, &TasoConfig::default());
        let taso_seed_s = t0.elapsed().as_secs_f64();

        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, teng) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        let taso_eng_s = t0.elapsed().as_secs_f64();

        // Warm column: fill a persistent cache once (untimed), then time
        // the repeat — the pure result-memo lookup path.
        let cache = SearchCache::new();
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let (_, _cold) = taso_optimise_cached(&g, &rules, &cost, &TasoConfig::default(), &cache);
        let t0 = Instant::now();
        let (_, twarm) = taso_optimise_cached(&g, &rules, &cost, &TasoConfig::default(), &cache);
        let taso_warm_s = t0.elapsed().as_secs_f64();
        let warm_hit = twarm.from_cache;

        let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-12);
        let ok = rel(geng.final_ms, gref.final_ms) < 1e-6
            && rel(teng.final_ms, tref.final_ms) < 1e-6
            && twarm.final_ms.to_bits() == teng.final_ms.to_bits()
            && warm_hit;
        workers = teng.threads;
        println!(
            "{:<15} {:>10.3} {:>10.3} {:>7.1}x {:>10.3} {:>10.3} {:>7.1}x {:>10.5} {:>9} {:>8}",
            info.name,
            greedy_seed_s,
            greedy_eng_s,
            greedy_seed_s / greedy_eng_s.max(1e-9),
            taso_seed_s,
            taso_eng_s,
            taso_seed_s / taso_eng_s.max(1e-9),
            taso_warm_s,
            teng.memo_hits,
            if ok { "yes" } else { "NO" }
        );
        json_rows.push(format!(
            concat!(
                "    {{\"graph\": \"{}\", \"greedy_seed_s\": {:.4}, \"greedy_engine_s\": {:.4}, ",
                "\"greedy_speedup\": {:.2}, \"taso_seed_s\": {:.4}, \"taso_engine_s\": {:.4}, ",
                "\"taso_speedup\": {:.2}, \"taso_warm_s\": {:.6}, \"warm_speedup\": {:.2}, ",
                "\"warm_is_cache_hit\": {}, \"engine_memo_hits\": {}, \"cost_parity\": {}}}"
            ),
            info.name,
            greedy_seed_s,
            greedy_eng_s,
            greedy_seed_s / greedy_eng_s.max(1e-9),
            taso_seed_s,
            taso_eng_s,
            taso_seed_s / taso_eng_s.max(1e-9),
            taso_warm_s,
            taso_eng_s / taso_warm_s.max(1e-9),
            warm_hit,
            teng.memo_hits,
            ok,
        ));
    }
    println!("engine workers (from SearchLog): {workers}");

    // `cargo bench` runs from the package root (rust/); the results file
    // lives beside CHANGES.md at the repository root.
    let out = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_search.json"
    } else {
        "BENCH_search.json"
    };
    let json = format!(
        "{{\n  \"bench\": \"fig7_opt_time\",\n  \"placeholder\": false,\n  \"engine_workers\": {},\n  \"rows\": [\n{}\n  ]\n}}\n",
        workers,
        json_rows.join(",\n")
    );
    match std::fs::write(out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
