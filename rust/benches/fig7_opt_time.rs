//! Bench: Fig. 7 — end-to-end optimisation time per graph for the two
//! deterministic search baselines (greedy / TASO). The RLFlow rollout side
//! of Fig. 7 needs trained artifacts and lives in
//! `rlflow experiment fig7`; this bench isolates the search costs, which
//! dominate TASO's bar in the paper.

use std::time::Instant;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::search::{greedy_optimise, taso_optimise, TasoConfig};
use rlflow::xfer::library::standard_library;

fn main() {
    let rules = standard_library();
    let cost = CostModel::new(DeviceProfile::rtx2070());
    println!(
        "{:<15} {:>12} {:>12} {:>10} {:>10}",
        "Graph", "greedy (s)", "taso (s)", "greedy %", "taso %"
    );
    for (info, g) in rlflow::zoo::all() {
        let t0 = Instant::now();
        let (_, glog) = greedy_optimise(&g, &rules, &cost, 50);
        let greedy_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let (_, tlog) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        let taso_s = t0.elapsed().as_secs_f64();

        println!(
            "{:<15} {:>12.3} {:>12.3} {:>9.1}% {:>9.1}%",
            info.name,
            greedy_s,
            taso_s,
            glog.improvement_pct(),
            tlog.improvement_pct()
        );
    }
}
