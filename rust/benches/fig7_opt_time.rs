//! Bench: Fig. 7 — end-to-end optimisation time per graph for the two
//! deterministic search baselines (greedy / TASO). The RLFlow rollout side
//! of Fig. 7 needs trained artifacts and lives in
//! `rlflow experiment fig7`; this bench isolates the search costs, which
//! dominate TASO's bar in the paper.
//!
//! Two rows per graph: the pre-engine sequential seed path (single thread,
//! no memoisation, full cost recompute per candidate — the `*_reference`
//! oracles) and the parallel memoised engine (scoped worker threads,
//! transposition table, incremental delta costing). The `speedup` column
//! is seed-time / engine-time; `cost ok` checks the engine found the same
//! final cost as the seed path (to 1e-6 relative).

use std::time::Instant;

use rlflow::cost::{CostModel, DeviceProfile};
use rlflow::search::{
    greedy_optimise, greedy_optimise_reference, taso_optimise, taso_optimise_reference,
    TasoConfig,
};
use rlflow::xfer::library::standard_library;

fn main() {
    let rules = standard_library();
    let mut workers = 0;
    println!(
        "{:<15} {:>10} {:>10} {:>8} {:>10} {:>10} {:>8} {:>9} {:>8}",
        "Graph",
        "greedy(s)",
        "g-eng(s)",
        "g-spd",
        "taso(s)",
        "t-eng(s)",
        "t-spd",
        "memohits",
        "cost ok"
    );
    for (info, g) in rlflow::zoo::all() {
        // Fresh cost model per timed run: the per-op cost cache persists
        // inside a CostModel, so sharing one would let the seed run warm
        // the cache for the engine run (or vice versa) and bias the
        // speedup columns.
        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, gref) = greedy_optimise_reference(&g, &rules, &cost, 50);
        let greedy_seed_s = t0.elapsed().as_secs_f64();

        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, geng) = greedy_optimise(&g, &rules, &cost, 50);
        let greedy_eng_s = t0.elapsed().as_secs_f64();

        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, tref) = taso_optimise_reference(&g, &rules, &cost, &TasoConfig::default());
        let taso_seed_s = t0.elapsed().as_secs_f64();

        let cost = CostModel::new(DeviceProfile::rtx2070());
        let t0 = Instant::now();
        let (_, teng) = taso_optimise(&g, &rules, &cost, &TasoConfig::default());
        let taso_eng_s = t0.elapsed().as_secs_f64();

        let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-12);
        let ok = rel(geng.final_ms, gref.final_ms) < 1e-6
            && rel(teng.final_ms, tref.final_ms) < 1e-6;
        workers = teng.threads;
        println!(
            "{:<15} {:>10.3} {:>10.3} {:>7.1}x {:>10.3} {:>10.3} {:>7.1}x {:>9} {:>8}",
            info.name,
            greedy_seed_s,
            greedy_eng_s,
            greedy_seed_s / greedy_eng_s.max(1e-9),
            taso_seed_s,
            taso_eng_s,
            taso_seed_s / taso_eng_s.max(1e-9),
            teng.memo_hits,
            if ok { "yes" } else { "NO" }
        );
    }
    println!("engine workers (from SearchLog): {workers}");
}
