//! Bench: host-backend training throughput — the versioned reduction
//! orders (V1 scalar vs V2 lane-tiled + sample-parallel training)
//! measured end to end.
//!
//! Seven kernel configurations run the same seeded synthetic workload:
//!
//!  * `seed_scalar` — the seed scalar triple-loop kernels
//!    (`KernelMode::Reference`), the pre-rework baseline;
//!  * `v1_t1`/`v1_t4`/`v1_t8` — cache-blocked `V1Scalar` kernels at 1,
//!    4 and 8 worker threads (the PR-5 configuration);
//!  * `v2_t1`/`v2_t4`/`v2_t8` — `V2LaneTiled` SIMD-lane kernels with
//!    sample-parallel train gradients at 1, 4 and 8 worker threads.
//!
//! Per program family the table reports ms/call and speedups over the
//! seed baseline. Parity is checked per order: `seed_scalar` and every
//! `v1_*` column must be bit-identical, every `v2_*` column must be
//! bit-identical, and the V1↔V2 pair must agree within a relative-error
//! bound (reported as `v1_v2_max_rel_err`). The final section times one
//! full train step (gnn_ae_train + wm_train + ctrl_train) per
//! configuration — end-to-end train steps/sec. Results are written to
//! BENCH_train.json at the repository root.

use std::time::Instant;

use rlflow::runtime::{
    Backend, HostBackend, HostConfig, KernelCfg, ParamStore, TensorView,
};
use rlflow::util::Rng;

const CONFIG_NAMES: [&str; 7] =
    ["seed_scalar", "v1_t1", "v1_t4", "v1_t8", "v2_t1", "v2_t4", "v2_t8"];

fn kernel_cfg(name: &str) -> KernelCfg {
    match name {
        "seed_scalar" => KernelCfg::reference(),
        "v1_t1" => KernelCfg::blocked(1),
        "v1_t4" => KernelCfg::blocked(4),
        "v1_t8" => KernelCfg::blocked(8),
        "v2_t1" => KernelCfg::v2(1),
        "v2_t4" => KernelCfg::v2(4),
        "v2_t8" => KernelCfg::v2(8),
        other => panic!("unknown config {other}"),
    }
}

/// Largest elementwise relative error between two signatures.
fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y).abs() / x.abs().max(y.abs()).max(1e-6)) as f64)
        .fold(0.0, f64::max)
}

/// Seeded synthetic workload sized to the backend's manifest.
struct Workload {
    n: usize,
    f: usize,
    z: usize,
    r: usize,
    x1: usize,
    locs: usize,
    b_enc: usize,
    b_dream: usize,
    b_ppo: usize,
    b_wm: usize,
    t_len: usize,
    // gnn
    feats: Vec<f32>,
    adj: Vec<f32>,
    mask: Vec<f32>,
    // ctrl
    zb: Vec<f32>,
    hb: Vec<f32>,
    zp: Vec<f32>,
    hp_: Vec<f32>,
    act: Vec<i32>,
    logp: Vec<f32>,
    adv: Vec<f32>,
    ret: Vec<f32>,
    xm: Vec<f32>,
    lm: Vec<f32>,
    // wm
    zd: Vec<f32>,
    ad: Vec<i32>,
    hd: Vec<f32>,
    cd: Vec<f32>,
    zt: Vec<f32>,
    at: Vec<i32>,
    zt_next: Vec<f32>,
    rt: Vec<f32>,
    xmt: Vec<f32>,
    dn: Vec<f32>,
    vl: Vec<f32>,
}

impl Workload {
    fn new(backend: &dyn Backend, seed: u64) -> Self {
        let m = backend.manifest();
        let hp = |k: &str| m.hp_usize(k).unwrap();
        let (n, f, z, r) = (hp("MAX_NODES"), hp("NODE_FEATS"), hp("LATENT"), hp("RNN_HIDDEN"));
        let (x1, locs) = (hp("N_XFERS1"), hp("MAX_LOCS"));
        let (b_enc, b_dream, b_ppo, b_wm, t_len) =
            (hp("B_ENC"), hp("B_DREAM"), hp("B_PPO"), hp("B_WM"), hp("SEQ_LEN"));
        let mut rng = Rng::new(seed);
        // Dense graph batch: every node live, chain + skip edges.
        let feats: Vec<f32> = (0..b_enc * n * f).map(|_| rng.normal() * 0.5).collect();
        let mut adj = vec![0.0f32; b_enc * n * n];
        for s in 0..b_enc {
            for i in 1..n {
                adj[s * n * n + (i - 1) * n + i] = 1.0;
                if i >= 4 {
                    adj[s * n * n + (i - 4) * n + i] = 1.0;
                }
            }
        }
        let mask = vec![1.0f32; b_enc * n];
        let zt: Vec<f32> = (0..b_wm * t_len * z).map(|_| rng.normal() * 0.5).collect();
        Self {
            n,
            f,
            z,
            r,
            x1,
            locs,
            b_enc,
            b_dream,
            b_ppo,
            b_wm,
            t_len,
            feats,
            adj,
            mask,
            zb: (0..b_dream * z).map(|_| rng.normal() * 0.4).collect(),
            hb: (0..b_dream * r).map(|_| rng.normal() * 0.2).collect(),
            zp: (0..b_ppo * z).map(|_| rng.normal() * 0.4).collect(),
            hp_: (0..b_ppo * r).map(|_| rng.normal() * 0.2).collect(),
            act: (0..b_ppo).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect(),
            logp: vec![-1.2; b_ppo],
            adv: (0..b_ppo).map(|i| if i % 2 == 0 { 1.0 } else { -0.7 }).collect(),
            ret: vec![0.3; b_ppo],
            xm: vec![1.0; b_ppo * x1],
            lm: vec![1.0; b_ppo * locs],
            zd: (0..b_dream * z).map(|_| rng.normal() * 0.5).collect(),
            ad: (0..b_dream).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect(),
            hd: vec![0.0; b_dream * r],
            cd: vec![0.0; b_dream * r],
            zt_next: zt.iter().map(|v| 0.9 * v).collect(),
            zt,
            at: (0..b_wm * t_len).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect(),
            rt: vec![0.05; b_wm * t_len],
            xmt: vec![1.0; b_wm * t_len * x1],
            dn: vec![0.0; b_wm * t_len],
            vl: vec![1.0; b_wm * t_len],
        }
    }

    fn gnn_rest(&self) -> Vec<TensorView<'_>> {
        vec![
            TensorView::f32(&self.feats, &[self.b_enc, self.n, self.f]),
            TensorView::f32(&self.adj, &[self.b_enc, self.n, self.n]),
            TensorView::f32(&self.mask, &[self.b_enc, self.n]),
        ]
    }

    fn ctrl_train_rest(&self) -> Vec<TensorView<'_>> {
        vec![
            TensorView::f32(&self.zp, &[self.b_ppo, self.z]),
            TensorView::f32(&self.hp_, &[self.b_ppo, self.r]),
            TensorView::i32(&self.act, &[self.b_ppo, 2]),
            TensorView::f32(&self.logp, &[self.b_ppo]),
            TensorView::f32(&self.adv, &[self.b_ppo]),
            TensorView::f32(&self.ret, &[self.b_ppo]),
            TensorView::f32(&self.xm, &[self.b_ppo, self.x1]),
            TensorView::f32(&self.lm, &[self.b_ppo, self.locs]),
            TensorView::ScalarF32(3e-4),
            TensorView::ScalarF32(0.2),
            TensorView::ScalarF32(0.01),
        ]
    }

    fn wm_train_rest(&self) -> Vec<TensorView<'_>> {
        let (b, t) = (self.b_wm, self.t_len);
        vec![
            TensorView::f32(&self.zt, &[b, t, self.z]),
            TensorView::i32(&self.at, &[b, t, 2]),
            TensorView::f32(&self.zt_next, &[b, t, self.z]),
            TensorView::f32(&self.rt, &[b, t]),
            TensorView::f32(&self.xmt, &[b, t, self.x1]),
            TensorView::f32(&self.dn, &[b, t]),
            TensorView::f32(&self.vl, &[b, t]),
            TensorView::ScalarF32(1e-3),
        ]
    }
}

fn bench<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warm-up (also warms the workspace arena)
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64 * 1e3
}

/// Per-config result: program -> ms/call, plus a parity signature.
struct ConfigRun {
    ms: Vec<(&'static str, f64)>,
    steps_per_s: f64,
    signature: Vec<f32>,
}

fn run_config(name: &str) -> ConfigRun {
    let backend =
        HostBackend::with_config(HostConfig { kernels: kernel_cfg(name), ..HostConfig::default() });
    let w = Workload::new(&backend, 0xBEEF);
    let gnn = ParamStore::init(&backend, "gnn", 0).unwrap();
    let wm = ParamStore::init(&backend, "wm", 1).unwrap();
    let ctrl = ParamStore::init(&backend, "ctrl", 2).unwrap();
    let mut ms: Vec<(&'static str, f64)> = Vec::new();
    let mut signature: Vec<f32> = Vec::new();

    // --- forward programs -------------------------------------------------
    let enc = backend.exec_with_params("gnn_encode_b", &gnn, &w.gnn_rest()).unwrap();
    signature.extend(&enc[0].data);
    ms.push((
        "gnn_encode_b",
        bench(3, || {
            let _ = backend.exec_with_params("gnn_encode_b", &gnn, &w.gnn_rest()).unwrap();
        }),
    ));
    let pol_rest = [
        TensorView::f32(&w.zb, &[w.b_dream, w.z]),
        TensorView::f32(&w.hb, &[w.b_dream, w.r]),
    ];
    let pol = backend.exec_with_params("ctrl_policy_b", &ctrl, &pol_rest).unwrap();
    for t in &pol {
        signature.extend(&t.data);
    }
    ms.push((
        "ctrl_policy_b",
        bench(50, || {
            let _ = backend.exec_with_params("ctrl_policy_b", &ctrl, &pol_rest).unwrap();
        }),
    ));
    let wm_rest = [
        TensorView::f32(&w.zd, &[w.b_dream, w.z]),
        TensorView::i32(&w.ad, &[w.b_dream, 2]),
        TensorView::f32(&w.hd, &[w.b_dream, w.r]),
        TensorView::f32(&w.cd, &[w.b_dream, w.r]),
    ];
    let step = backend.exec_with_params("wm_step_b", &wm, &wm_rest).unwrap();
    for t in &step {
        signature.extend(&t.data);
    }
    ms.push((
        "wm_step_b",
        bench(100, || {
            let _ = backend.exec_with_params("wm_step_b", &wm, &wm_rest).unwrap();
        }),
    ));

    // --- train programs (fresh stores per timed section so the Adam
    // trajectory is identical in every configuration) ---------------------
    let mut g2 = ParamStore::init(&backend, "gnn", 7).unwrap();
    ms.push((
        "gnn_ae_train",
        bench(3, || {
            let _ = backend.train_step("gnn_ae_train", &mut g2, &w.gnn_rest()).unwrap();
        }),
    ));
    signature.extend(&g2.theta);
    let mut c2 = ParamStore::init(&backend, "ctrl", 8).unwrap();
    ms.push((
        "ctrl_train",
        bench(20, || {
            let _ = backend.train_step("ctrl_train", &mut c2, &w.ctrl_train_rest()).unwrap();
        }),
    ));
    signature.extend(&c2.theta);
    let mut w2 = ParamStore::init(&backend, "wm", 9).unwrap();
    ms.push((
        "wm_train",
        bench(10, || {
            let _ = backend.train_step("wm_train", &mut w2, &w.wm_train_rest()).unwrap();
        }),
    ));
    signature.extend(&w2.theta);

    // --- end-to-end: one full train step = AE + WM + PPO ------------------
    let mut ge = ParamStore::init(&backend, "gnn", 17).unwrap();
    let mut we = ParamStore::init(&backend, "wm", 18).unwrap();
    let mut ce = ParamStore::init(&backend, "ctrl", 19).unwrap();
    let per_step = bench(3, || {
        let _ = backend.train_step("gnn_ae_train", &mut ge, &w.gnn_rest()).unwrap();
        let _ = backend.train_step("wm_train", &mut we, &w.wm_train_rest()).unwrap();
        let _ = backend.train_step("ctrl_train", &mut ce, &w.ctrl_train_rest()).unwrap();
    });
    ConfigRun { ms, steps_per_s: 1e3 / per_step, signature }
}

fn main() {
    let runs: Vec<ConfigRun> = CONFIG_NAMES.iter().map(|n| run_config(n)).collect();
    // Per-order bit parity: seed + every v1_* column; every v2_* column.
    let v1_bitwise = runs[..4].iter().all(|r| r.signature == runs[0].signature);
    let v2_bitwise = runs[4..].iter().all(|r| r.signature == runs[4].signature);
    let cross_err = max_rel_err(&runs[0].signature, &runs[4].signature);

    println!(
        "{:<15} {:>10} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "program", "seed ms", "v1 t1", "v1 t8", "v2 t1", "v2 t8", "v2t8 spdup", "v2/v1 t8"
    );
    let mut json_rows = Vec::new();
    for (pi, &(prog, _)) in runs[0].ms.iter().enumerate() {
        let col = |ci: usize| runs[ci].ms[pi].1;
        let spdup_v1 = col(0) / col(3).max(1e-9);
        let spdup_v2 = col(0) / col(6).max(1e-9);
        let v2_over_v1 = col(3) / col(6).max(1e-9);
        println!(
            "{:<15} {:>10.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.2}x {:>9.2}x",
            prog,
            col(0),
            col(1),
            col(3),
            col(4),
            col(6),
            spdup_v2,
            v2_over_v1,
        );
        json_rows.push(format!(
            concat!(
                "    {{\"program\": \"{}\", \"seed_scalar_ms\": {:.4}, ",
                "\"v1_t1_ms\": {:.4}, \"v1_t4_ms\": {:.4}, \"v1_t8_ms\": {:.4}, ",
                "\"v2_t1_ms\": {:.4}, \"v2_t4_ms\": {:.4}, \"v2_t8_ms\": {:.4}, ",
                "\"speedup_v1_t8\": {:.3}, \"speedup_v2_t8\": {:.3}, ",
                "\"speedup_v2_over_v1_t8\": {:.3}}}"
            ),
            prog,
            col(0),
            col(1),
            col(2),
            col(3),
            col(4),
            col(5),
            col(6),
            spdup_v1,
            spdup_v2,
            v2_over_v1,
        ));
    }
    println!();
    for (ci, name) in CONFIG_NAMES.iter().enumerate() {
        println!("end-to-end train steps/sec [{name:>12}]: {:.2}", runs[ci].steps_per_s);
    }
    println!("V1 parity (seed + v1_*): {}", if v1_bitwise { "ok" } else { "DIVERGED" });
    println!("V2 parity (v2_*): {}", if v2_bitwise { "ok" } else { "DIVERGED" });
    println!("V1<->V2 max relative error: {cross_err:.3e}");

    // `cargo bench` runs from the package root (rust/); the results file
    // lives beside CHANGES.md at the repository root.
    let out = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_train.json"
    } else {
        "BENCH_train.json"
    };
    let steps: Vec<String> = CONFIG_NAMES
        .iter()
        .zip(&runs)
        .map(|(n, r)| format!("\"{}\": {:.3}", n, r.steps_per_s))
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"bench\": \"fig_train_throughput\",\n  \"placeholder\": false,\n",
            "  \"parity\": {{\"v1_bitwise\": {}, \"v2_bitwise\": {}, ",
            "\"v1_v2_max_rel_err\": {:.6e}}},\n  \"rows\": [\n{}\n  ],\n",
            "  \"end_to_end_train_steps_per_s\": {{{}}}\n}}\n"
        ),
        v1_bitwise,
        v2_bitwise,
        cross_err,
        json_rows.join(",\n"),
        steps.join(", ")
    );
    match std::fs::write(out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
