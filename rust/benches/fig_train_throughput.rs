//! Bench: host-backend training throughput — the PR-5 kernel/Workspace
//! rework measured end to end.
//!
//! Four kernel configurations run the same seeded synthetic workload:
//!
//!  * `seed_scalar` — the seed scalar triple-loop kernels
//!    (`KernelMode::Reference`), the pre-rework baseline;
//!  * `blocked_t1`  — cache-blocked kernels, single thread;
//!  * `blocked_t4`  — blocked kernels, 4 worker threads;
//!  * `blocked_t8`  — blocked kernels, 8 worker threads.
//!
//! Per program family the table reports ms/call and the speedup of each
//! blocked column over the seed scalar baseline, plus a `parity` column
//! checking the outputs are bit-identical across all four configurations
//! (the kernel determinism contract). The final section times one full
//! train step (gnn_ae_train + wm_train + ctrl_train) per configuration —
//! end-to-end train steps/sec. Results are written to BENCH_train.json at
//! the repository root.

use std::time::Instant;

use rlflow::runtime::{
    Backend, HostBackend, HostConfig, KernelCfg, ParamStore, TensorView,
};
use rlflow::util::Rng;

const CONFIG_NAMES: [&str; 4] = ["seed_scalar", "blocked_t1", "blocked_t4", "blocked_t8"];

fn kernel_cfg(name: &str) -> KernelCfg {
    match name {
        "seed_scalar" => KernelCfg::reference(),
        "blocked_t1" => KernelCfg::blocked(1),
        "blocked_t4" => KernelCfg::blocked(4),
        "blocked_t8" => KernelCfg::blocked(8),
        other => panic!("unknown config {other}"),
    }
}

/// Seeded synthetic workload sized to the backend's manifest.
struct Workload {
    n: usize,
    f: usize,
    z: usize,
    r: usize,
    x1: usize,
    locs: usize,
    b_enc: usize,
    b_dream: usize,
    b_ppo: usize,
    b_wm: usize,
    t_len: usize,
    // gnn
    feats: Vec<f32>,
    adj: Vec<f32>,
    mask: Vec<f32>,
    // ctrl
    zb: Vec<f32>,
    hb: Vec<f32>,
    zp: Vec<f32>,
    hp_: Vec<f32>,
    act: Vec<i32>,
    logp: Vec<f32>,
    adv: Vec<f32>,
    ret: Vec<f32>,
    xm: Vec<f32>,
    lm: Vec<f32>,
    // wm
    zd: Vec<f32>,
    ad: Vec<i32>,
    hd: Vec<f32>,
    cd: Vec<f32>,
    zt: Vec<f32>,
    at: Vec<i32>,
    zt_next: Vec<f32>,
    rt: Vec<f32>,
    xmt: Vec<f32>,
    dn: Vec<f32>,
    vl: Vec<f32>,
}

impl Workload {
    fn new(backend: &dyn Backend, seed: u64) -> Self {
        let m = backend.manifest();
        let hp = |k: &str| m.hp_usize(k).unwrap();
        let (n, f, z, r) = (hp("MAX_NODES"), hp("NODE_FEATS"), hp("LATENT"), hp("RNN_HIDDEN"));
        let (x1, locs) = (hp("N_XFERS1"), hp("MAX_LOCS"));
        let (b_enc, b_dream, b_ppo, b_wm, t_len) =
            (hp("B_ENC"), hp("B_DREAM"), hp("B_PPO"), hp("B_WM"), hp("SEQ_LEN"));
        let mut rng = Rng::new(seed);
        // Dense graph batch: every node live, chain + skip edges.
        let feats: Vec<f32> = (0..b_enc * n * f).map(|_| rng.normal() * 0.5).collect();
        let mut adj = vec![0.0f32; b_enc * n * n];
        for s in 0..b_enc {
            for i in 1..n {
                adj[s * n * n + (i - 1) * n + i] = 1.0;
                if i >= 4 {
                    adj[s * n * n + (i - 4) * n + i] = 1.0;
                }
            }
        }
        let mask = vec![1.0f32; b_enc * n];
        let zt: Vec<f32> = (0..b_wm * t_len * z).map(|_| rng.normal() * 0.5).collect();
        Self {
            n,
            f,
            z,
            r,
            x1,
            locs,
            b_enc,
            b_dream,
            b_ppo,
            b_wm,
            t_len,
            feats,
            adj,
            mask,
            zb: (0..b_dream * z).map(|_| rng.normal() * 0.4).collect(),
            hb: (0..b_dream * r).map(|_| rng.normal() * 0.2).collect(),
            zp: (0..b_ppo * z).map(|_| rng.normal() * 0.4).collect(),
            hp_: (0..b_ppo * r).map(|_| rng.normal() * 0.2).collect(),
            act: (0..b_ppo).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect(),
            logp: vec![-1.2; b_ppo],
            adv: (0..b_ppo).map(|i| if i % 2 == 0 { 1.0 } else { -0.7 }).collect(),
            ret: vec![0.3; b_ppo],
            xm: vec![1.0; b_ppo * x1],
            lm: vec![1.0; b_ppo * locs],
            zd: (0..b_dream * z).map(|_| rng.normal() * 0.5).collect(),
            ad: (0..b_dream).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect(),
            hd: vec![0.0; b_dream * r],
            cd: vec![0.0; b_dream * r],
            zt_next: zt.iter().map(|v| 0.9 * v).collect(),
            zt,
            at: (0..b_wm * t_len).flat_map(|i| [(i % x1) as i32, (i % locs) as i32]).collect(),
            rt: vec![0.05; b_wm * t_len],
            xmt: vec![1.0; b_wm * t_len * x1],
            dn: vec![0.0; b_wm * t_len],
            vl: vec![1.0; b_wm * t_len],
        }
    }

    fn gnn_rest(&self) -> Vec<TensorView<'_>> {
        vec![
            TensorView::f32(&self.feats, &[self.b_enc, self.n, self.f]),
            TensorView::f32(&self.adj, &[self.b_enc, self.n, self.n]),
            TensorView::f32(&self.mask, &[self.b_enc, self.n]),
        ]
    }

    fn ctrl_train_rest(&self) -> Vec<TensorView<'_>> {
        vec![
            TensorView::f32(&self.zp, &[self.b_ppo, self.z]),
            TensorView::f32(&self.hp_, &[self.b_ppo, self.r]),
            TensorView::i32(&self.act, &[self.b_ppo, 2]),
            TensorView::f32(&self.logp, &[self.b_ppo]),
            TensorView::f32(&self.adv, &[self.b_ppo]),
            TensorView::f32(&self.ret, &[self.b_ppo]),
            TensorView::f32(&self.xm, &[self.b_ppo, self.x1]),
            TensorView::f32(&self.lm, &[self.b_ppo, self.locs]),
            TensorView::ScalarF32(3e-4),
            TensorView::ScalarF32(0.2),
            TensorView::ScalarF32(0.01),
        ]
    }

    fn wm_train_rest(&self) -> Vec<TensorView<'_>> {
        let (b, t) = (self.b_wm, self.t_len);
        vec![
            TensorView::f32(&self.zt, &[b, t, self.z]),
            TensorView::i32(&self.at, &[b, t, 2]),
            TensorView::f32(&self.zt_next, &[b, t, self.z]),
            TensorView::f32(&self.rt, &[b, t]),
            TensorView::f32(&self.xmt, &[b, t, self.x1]),
            TensorView::f32(&self.dn, &[b, t]),
            TensorView::f32(&self.vl, &[b, t]),
            TensorView::ScalarF32(1e-3),
        ]
    }
}

fn bench<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warm-up (also warms the workspace arena)
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64 * 1e3
}

/// Per-config result: program -> ms/call, plus a parity signature.
struct ConfigRun {
    ms: Vec<(&'static str, f64)>,
    steps_per_s: f64,
    signature: Vec<f32>,
}

fn run_config(name: &str) -> ConfigRun {
    let backend =
        HostBackend::with_config(HostConfig { kernels: kernel_cfg(name), ..HostConfig::default() });
    let w = Workload::new(&backend, 0xBEEF);
    let gnn = ParamStore::init(&backend, "gnn", 0).unwrap();
    let wm = ParamStore::init(&backend, "wm", 1).unwrap();
    let ctrl = ParamStore::init(&backend, "ctrl", 2).unwrap();
    let mut ms: Vec<(&'static str, f64)> = Vec::new();
    let mut signature: Vec<f32> = Vec::new();

    // --- forward programs -------------------------------------------------
    let enc = backend.exec_with_params("gnn_encode_b", &gnn, &w.gnn_rest()).unwrap();
    signature.extend(&enc[0].data);
    ms.push((
        "gnn_encode_b",
        bench(3, || {
            let _ = backend.exec_with_params("gnn_encode_b", &gnn, &w.gnn_rest()).unwrap();
        }),
    ));
    let pol_rest = [
        TensorView::f32(&w.zb, &[w.b_dream, w.z]),
        TensorView::f32(&w.hb, &[w.b_dream, w.r]),
    ];
    let pol = backend.exec_with_params("ctrl_policy_b", &ctrl, &pol_rest).unwrap();
    for t in &pol {
        signature.extend(&t.data);
    }
    ms.push((
        "ctrl_policy_b",
        bench(50, || {
            let _ = backend.exec_with_params("ctrl_policy_b", &ctrl, &pol_rest).unwrap();
        }),
    ));
    let wm_rest = [
        TensorView::f32(&w.zd, &[w.b_dream, w.z]),
        TensorView::i32(&w.ad, &[w.b_dream, 2]),
        TensorView::f32(&w.hd, &[w.b_dream, w.r]),
        TensorView::f32(&w.cd, &[w.b_dream, w.r]),
    ];
    let step = backend.exec_with_params("wm_step_b", &wm, &wm_rest).unwrap();
    for t in &step {
        signature.extend(&t.data);
    }
    ms.push((
        "wm_step_b",
        bench(100, || {
            let _ = backend.exec_with_params("wm_step_b", &wm, &wm_rest).unwrap();
        }),
    ));

    // --- train programs (fresh stores per timed section so the Adam
    // trajectory is identical in every configuration) ---------------------
    let mut g2 = ParamStore::init(&backend, "gnn", 7).unwrap();
    ms.push((
        "gnn_ae_train",
        bench(3, || {
            let _ = backend.train_step("gnn_ae_train", &mut g2, &w.gnn_rest()).unwrap();
        }),
    ));
    signature.extend(&g2.theta);
    let mut c2 = ParamStore::init(&backend, "ctrl", 8).unwrap();
    ms.push((
        "ctrl_train",
        bench(20, || {
            let _ = backend.train_step("ctrl_train", &mut c2, &w.ctrl_train_rest()).unwrap();
        }),
    ));
    signature.extend(&c2.theta);
    let mut w2 = ParamStore::init(&backend, "wm", 9).unwrap();
    ms.push((
        "wm_train",
        bench(10, || {
            let _ = backend.train_step("wm_train", &mut w2, &w.wm_train_rest()).unwrap();
        }),
    ));
    signature.extend(&w2.theta);

    // --- end-to-end: one full train step = AE + WM + PPO ------------------
    let mut ge = ParamStore::init(&backend, "gnn", 17).unwrap();
    let mut we = ParamStore::init(&backend, "wm", 18).unwrap();
    let mut ce = ParamStore::init(&backend, "ctrl", 19).unwrap();
    let per_step = bench(3, || {
        let _ = backend.train_step("gnn_ae_train", &mut ge, &w.gnn_rest()).unwrap();
        let _ = backend.train_step("wm_train", &mut we, &w.wm_train_rest()).unwrap();
        let _ = backend.train_step("ctrl_train", &mut ce, &w.ctrl_train_rest()).unwrap();
    });
    ConfigRun { ms, steps_per_s: 1e3 / per_step, signature }
}

fn main() {
    let runs: Vec<ConfigRun> = CONFIG_NAMES.iter().map(|n| run_config(n)).collect();
    let parity = runs.iter().all(|r| r.signature == runs[0].signature);

    println!(
        "{:<15} {:>12} {:>12} {:>12} {:>12} {:>9} {:>7}",
        "program", "seed ms", "blocked t1", "blocked t4", "blocked t8", "t8 spdup", "parity"
    );
    let mut json_rows = Vec::new();
    for (pi, &(prog, _)) in runs[0].ms.iter().enumerate() {
        let col = |ci: usize| runs[ci].ms[pi].1;
        let spdup = col(0) / col(3).max(1e-9);
        println!(
            "{:<15} {:>12.3} {:>12.3} {:>12.3} {:>12.3} {:>8.2}x {:>7}",
            prog,
            col(0),
            col(1),
            col(2),
            col(3),
            spdup,
            if parity { "ok" } else { "DIVERGED" },
        );
        json_rows.push(format!(
            concat!(
                "    {{\"program\": \"{}\", \"seed_scalar_ms\": {:.4}, \"blocked_t1_ms\": {:.4}, ",
                "\"blocked_t4_ms\": {:.4}, \"blocked_t8_ms\": {:.4}, \"speedup_t8\": {:.3}}}"
            ),
            prog,
            col(0),
            col(1),
            col(2),
            col(3),
            spdup,
        ));
    }
    println!();
    for (ci, name) in CONFIG_NAMES.iter().enumerate() {
        println!("end-to-end train steps/sec [{name:>12}]: {:.2}", runs[ci].steps_per_s);
    }
    println!("output parity across configurations: {}", if parity { "ok" } else { "DIVERGED" });

    // `cargo bench` runs from the package root (rust/); the results file
    // lives beside CHANGES.md at the repository root.
    let out = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_train.json"
    } else {
        "BENCH_train.json"
    };
    let steps: Vec<String> = CONFIG_NAMES
        .iter()
        .zip(&runs)
        .map(|(n, r)| format!("\"{}\": {:.3}", n, r.steps_per_s))
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"bench\": \"fig_train_throughput\",\n  \"placeholder\": false,\n",
            "  \"parity\": {},\n  \"rows\": [\n{}\n  ],\n",
            "  \"end_to_end_train_steps_per_s\": {{{}}}\n}}\n"
        ),
        parity,
        json_rows.join(",\n"),
        steps.join(", ")
    );
    match std::fs::write(out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
